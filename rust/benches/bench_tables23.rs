//! Tables 2–3 regeneration: per-power-of-two-magnitude-bin weight
//! percentages of 4/5/6-bit LBW vs full-precision weights, for a
//! residual-block conv layer (Table 2) and a head layer (Table 3).
//!
//! The paper's structural claims, checked in-line:
//!   * the 4-bit column is dominated by exact zeros (>82% / >58%),
//!   * the top-magnitude rows are IDENTICAL across 4/5/6-bit columns
//!     (all bit-widths encode the large weights the same way),
//!   * the 6-bit column approaches the float column on most rows.

use std::path::Path;

use lbw_net::coordinator::params::{Checkpoint, ParamSpec};
use lbw_net::data::Rng;
use lbw_net::quant::{stats, threshold};
use lbw_net::runtime::default_artifacts_dir;
use lbw_net::util::bench::run;

fn table_for(name: &str, w: &[f32], lo: i32) {
    let q4 = threshold::lbw_quantize_layer(w, 4, 0.75);
    let q5 = threshold::lbw_quantize_layer(w, 5, 0.75);
    let q6 = threshold::lbw_quantize_layer(w, 6, 0.75);
    println!("--- {name} ({} weights) ---", w.len());
    println!(
        "{}",
        stats::render_bin_table(
            &[
                ("4-bit LBW", &q4.wq),
                ("5-bit LBW", &q5.wq),
                ("6-bit LBW", &q6.wq),
                ("32-bit float", w),
            ],
            lo,
            0,
        )
    );
    println!(
        "zeros: 4-bit {:.1}% | 5-bit {:.1}% | 6-bit {:.1}%",
        q4.sparsity() * 100.0,
        q5.sparsity() * 100.0,
        q6.sparsity() * 100.0
    );
    // structural check: the top-2 magnitude bins agree across bit-widths
    let t4 = stats::pow2_bin_table(&q4.wq, lo, 0);
    let t5 = stats::pow2_bin_table(&q5.wq, lo, 0);
    let t6 = stats::pow2_bin_table(&q6.wq, lo, 0);
    let last = t4.len() - 1;
    let agree = (last - 1..=last).all(|r| {
        (t4[r].pct - t5[r].pct).abs() < 1e-9 && (t5[r].pct - t6[r].pct).abs() < 1e-9
    });
    println!(
        "top-magnitude rows identical across 4/5/6-bit: {} (paper: identical)\n",
        if agree { "YES" } else { "NO" }
    );
}

fn main() {
    println!("=== bench_tables23: weight magnitude distribution (Tables 2-3) ===\n");
    let ckpt_path = Path::new("train_detect_b6.lbw");
    if ckpt_path.exists() && default_artifacts_dir().join("param_spec_a.json").exists() {
        let ck = Checkpoint::load(ckpt_path).unwrap();
        let spec = ParamSpec::load_from_dir(&default_artifacts_dir(), &ck.arch).unwrap();
        let w2 = spec.view(&ck.params, "s2.b0.conv2.w").unwrap();
        table_for("Table 2 analogue: residual-block conv (trained)", w2, -16);
        let w3 = spec.view(&ck.params, "cls.w").unwrap();
        table_for("Table 3 analogue: detection head (trained, RPN stand-in)", w3, -19);
    } else {
        println!("(no trained checkpoint; synthetic heavy-tailed stand-ins)\n");
        let mut rng = Rng::new(5);
        let w2: Vec<f32> =
            (0..36_864).map(|_| rng.normal() * 0.03 * (1.0 + rng.normal().abs())).collect();
        table_for("Table 2 analogue: residual-block-sized layer", &w2, -16);
        let w3: Vec<f32> =
            (0..2_880).map(|_| rng.normal() * 0.01 * (1.0 + rng.normal().abs())).collect();
        table_for("Table 3 analogue: head-sized layer", &w3, -19);
    }

    println!("=== bin-table computation throughput ===");
    let mut rng = Rng::new(6);
    let w: Vec<f32> = (0..117_377).map(|_| rng.normal() * 0.02).collect();
    run("pow2_bin_table N=117k, 18 bins", 300, || stats::pow2_bin_table(&w, -16, 0));
}
