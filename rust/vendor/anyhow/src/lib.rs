//! In-tree offline substitute for the `anyhow` crate.
//!
//! The build is fully offline (see `lbw_net::util`), so this crate
//! re-implements the small slice of anyhow's API the workspace uses:
//! [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`]
//! macros, and the [`Context`] extension trait for `Result` and
//! `Option`. Errors carry a flattened context chain as text — enough
//! for CLI diagnostics and test assertions; no backtraces, no
//! downcasting.

use std::fmt;

/// A flattened error: the newest context first, separated by `": "`
/// like anyhow's `{:#}` rendering.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from anything displayable (the `anyhow!` entry point).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer.
    pub fn wrap(self, ctx: impl fmt::Display) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, so this blanket conversion can exist without
// colliding with `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable
/// value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/lbw/path")
            .context("reading the thing")?;
        Ok(s)
    }

    #[test]
    fn question_mark_and_context() {
        let e = io_fail().unwrap_err();
        let text = e.to_string();
        assert!(text.starts_with("reading the thing: "), "{text}");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 7;
        let e = anyhow!("value {x} and {}", 8);
        assert_eq!(e.to_string(), "value 7 and 8");

        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            bail!("unreachable {}", 1)
        }
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(f(true).unwrap_err().to_string(), "unreachable 1");
    }

    #[test]
    fn bare_ensure_names_condition() {
        fn f(n: u32) -> Result<()> {
            ensure!(n > 3);
            Ok(())
        }
        assert!(f(1).unwrap_err().to_string().contains("n > 3"));
        assert!(f(4).is_ok());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        assert_eq!(Some(3u32).with_context(|| "x").unwrap(), 3);
    }

    #[test]
    fn displayable_value_into_error() {
        let e = anyhow!(String::from("owned message"));
        assert_eq!(e.to_string(), "owned message");
    }
}
