//! Offline stub of the `xla` PJRT bindings.
//!
//! The deployment story of this repo is the pure-Rust engine
//! (`lbw_net::nn`); the PJRT artifact path is an *optional* fast path
//! that needs the real `xla_extension` bindings. This stub keeps that
//! path compiling in the fully-offline build: [`Literal`] is a real,
//! working host-side tensor container (so literal marshalling helpers
//! and their tests behave), while [`PjRtClient::cpu`] — the only way
//! to reach device execution — reports that PJRT is unavailable.
//!
//! Swapping in the real crate is a one-line change in the workspace
//! `Cargo.toml` (point the `xla` dependency at the real bindings); the
//! API surface below mirrors it.

use std::fmt;

/// Stub error type (mirrors `xla::Error` closely enough for `{e:?}`
/// formatting and `?` conversion into `anyhow::Error`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "PJRT runtime unavailable: this is the offline xla stub — \
     use the hermetic engine serving mode, or build against the real \
     xla_extension bindings (see README, \"Serving modes\")";

/// Element types the in-tree code marshals. Sealed to f32/i32.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>, dims: Vec<i64>) -> Literal;
    fn unwrap(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>, dims: Vec<i64>) -> Literal {
        Literal::F32 { data, dims }
    }
    fn unwrap(lit: &Literal) -> Result<Vec<f32>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            other => Err(Error(format!("literal is not f32: {other:?}"))),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>, dims: Vec<i64>) -> Literal {
        Literal::I32 { data, dims }
    }
    fn unwrap(lit: &Literal) -> Result<Vec<i32>> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            other => Err(Error(format!("literal is not i32: {other:?}"))),
        }
    }
}

/// Host-side literal: dense f32/i32 buffers plus shape, or a tuple.
/// Fully functional (unlike the execution types below).
#[derive(Debug, Clone)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let dims = vec![data.len() as i64];
        T::wrap(data.to_vec(), dims)
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        T::wrap(vec![v], vec![])
    }

    fn len(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
            Literal::Tuple(parts) => parts.len(),
        }
    }

    /// Reshape, checking the element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.len() {
            return Err(Error(format!(
                "reshape {:?}: {} elements into {} slots",
                dims,
                self.len(),
                want
            )));
        }
        let mut out = self.clone();
        match &mut out {
            Literal::F32 { dims: d, .. } | Literal::I32 { dims: d, .. } => {
                *d = dims.to_vec();
            }
            Literal::Tuple(_) => return Err(Error("cannot reshape a tuple".into())),
        }
        Ok(out)
    }

    /// Copy the buffer out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
    }

    /// First element of the buffer (scalars in the train-step outputs).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::unwrap(self)?
            .first()
            .copied()
            .ok_or_else(|| Error("empty literal".into()))
    }

    /// Unpack a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            other => Ok(vec![other]),
        }
    }
}

/// Parsed HLO module (stub: the text is held but never compiled).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// Computation wrapper (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client. [`PjRtClient::cpu`] always fails in the stub — device
/// execution needs the real bindings.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(UNAVAILABLE.into()))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Compiled executable (stub: unreachable without a client).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Mirrors the real replica-major output nesting.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Device buffer (stub: unreachable without a client).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(UNAVAILABLE.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32_i32() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
        assert!(l.to_vec::<i32>().is_err());
        let i = Literal::vec1(&[5i32, 6, 7]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![5, 6, 7]);
        assert_eq!(i.get_first_element::<i32>().unwrap(), 5);
    }

    #[test]
    fn reshape_checks_count() {
        assert!(Literal::vec1(&[1.0f32]).reshape(&[2]).is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(4.5f32);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 4.5);
        let t = Literal::Tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2i32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline xla stub"));
    }
}
