//! Accuracy-trajectory benchmark — thin driver over the experiment
//! lab.
//!
//! The training cells (float pre-train, then per-method fine-tunes and
//! INQ resuming from the float checkpoint, per seed) live in
//! `lbw_net::lab::runner`; this binary just picks a plan and runs the
//! train task:
//!
//! * default (smoke, CI): the committed `plans/ci-smoke.toml`, train
//!   trials only — the same content-addressed run directory as
//!   `repro lab run ci-smoke --only train`, so completed cells resume
//!   instead of re-training, and `BENCH_train.json` is regenerated in
//!   place (identical-cell re-runs can no longer clobber or duplicate
//!   trajectory rows).
//! * `--full`: a built-in deep profile — 3000 float steps, 1000
//!   fine-tune steps, 2000 train scenes, seeds {17, 18, 19}.

use std::path::Path;

use anyhow::{Context, Result};

use lbw_net::lab::plan::{Plan, TrainGrid, KNOWN_METHODS};
use lbw_net::lab::runner::{self, RunOpts};
use lbw_net::lab::store::LabStore;

fn full_plan() -> Plan {
    Plan {
        name: "bench-train-full".to_string(),
        repeats: 1,
        seed: 4242,
        requests: 48,
        concurrency: 8,
        serve: None,
        train: Some(TrainGrid {
            profile: "full".to_string(),
            methods: KNOWN_METHODS.iter().map(|s| s.to_string()).collect(),
            seeds: vec![17, 18, 19],
            width: 8,
            batch: 8,
            float_steps: 3000,
            float_lr: 0.05,
            ft_steps: 1000,
            ft_lr: 0.01,
            train_scenes: 2000,
            eval_scenes: 256,
        }),
    }
}

fn main() -> Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let plan = if full {
        full_plan()
    } else {
        Plan::load(Path::new("plans/ci-smoke.toml"))
            .context("bench_train smoke drives the committed CI plan")?
    };
    println!(
        "bench_train ({}): plan `{}` -> {}",
        if full { "full" } else { "smoke" },
        plan.name,
        plan.run_id()
    );
    let store = LabStore::new(LabStore::default_root());
    let opts = RunOpts { force: false, only: Some("train".to_string()), quiet: false };
    let report = runner::run_plan(&plan, &store, &opts)?;
    println!(
        "{} executed, {} resumed -> {}",
        report.executed,
        report.resumed,
        report.run_dir.display()
    );
    let (_serve_rows, train_rows) = runner::export_flat(
        &store,
        &report.run_id,
        Path::new("BENCH_serve.json"),
        Path::new("BENCH_train.json"),
    )?;
    println!("\n--- summary ({} train rows -> BENCH_train.json) ---", train_rows.len());
    runner::print_train_summary(&train_rows);
    Ok(())
}
