//! Trained-checkpoint accuracy trajectory: the paper's Table-1 loop,
//! hermetic. Train the float µResNet detector on SynthVOC, then carry
//! each checkpoint through every quantization method — exact ternary
//! (Theorem 1, b = 2), the semi-analytical LBW threshold at 4 and 6
//! bits, a DoReFa straight-through uniform baseline at 6 bits, and INQ
//! partitioned freezing at 6 bits — re-training each with projected
//! SGD and scoring held-out mAP. One `BENCH_train.json` row per
//! {method × bits × seed} with mAP, quantization distance ‖Wq − W‖₂,
//! zero-weight sparsity, compression ratio, first/last loss, and wall
//! time. `scripts/accuracy_gate.py` gates the result (6-bit within a
//! fixed mAP delta of float; ternary above a floor; error monotone in
//! bit-width).
//!
//! Fully hermetic: runs on a clean checkout with no Python and no
//! artifacts (`nn::grad` supplies the backward pass).
//!
//! Run with: `cargo run --release --example bench_train -- --smoke`
//! (the CI profile: 600 float + 200 fine-tune steps, 2 seeds, ~2 min).
//! The full profile (`--full`) stretches to 3000 + 1000 steps on 3
//! seeds for a smoother trajectory.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;
use lbw_net::coordinator::inq::train_inq_hermetic;
use lbw_net::coordinator::trainer::{
    write_bench_train, HermeticTrainer, TrainConfig, TrainMethod, TrainRow,
};
use lbw_net::quant::threshold::compression_ratio;

/// INQ cumulative-freeze schedule (the INQ paper's default).
const INQ_PHASES: [f64; 4] = [0.5, 0.75, 0.875, 1.0];

struct Profile {
    name: &'static str,
    width: usize,
    batch: usize,
    float_steps: u64,
    float_lr: f32,
    ft_steps: u64,
    ft_lr: f32,
    train_scenes: u64,
    eval_scenes: u64,
    seeds: &'static [u64],
}

const SMOKE: Profile = Profile {
    name: "smoke",
    width: 8,
    batch: 8,
    float_steps: 600,
    float_lr: 0.05,
    ft_steps: 200,
    ft_lr: 0.01,
    train_scenes: 256,
    eval_scenes: 48,
    seeds: &[17, 18],
};

const FULL: Profile = Profile {
    name: "full",
    width: 8,
    batch: 8,
    float_steps: 3000,
    float_lr: 0.05,
    ft_steps: 1000,
    ft_lr: 0.01,
    train_scenes: 2000,
    eval_scenes: 256,
    seeds: &[17, 18, 19],
};

fn base_cfg(p: &Profile, seed: u64) -> TrainConfig {
    TrainConfig {
        seed,
        steps: p.float_steps,
        lr: p.float_lr,
        train_scenes: p.train_scenes,
        eval_scenes: p.eval_scenes,
        log_every: 100,
        ..Default::default()
    }
}

#[allow(clippy::too_many_arguments)]
fn row(
    p: &Profile,
    method: &str,
    bits: u32,
    seed: u64,
    steps: u64,
    map: f64,
    quant_dist: f64,
    sparsity: f64,
    loss_first: f64,
    loss_last: f64,
    wall_s: f64,
) -> TrainRow {
    TrainRow {
        method: method.to_string(),
        bits,
        seed,
        steps,
        profile: p.name.to_string(),
        map,
        quant_dist,
        sparsity,
        compression: if bits >= 32 { 1.0 } else { compression_ratio(bits) },
        loss_first,
        loss_last,
        wall_s,
    }
}

fn main() -> Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let p = if full { FULL } else { SMOKE };
    println!(
        "bench_train [{}]: {} float + {} ft steps, {} train / {} eval scenes, seeds {:?}",
        p.name, p.float_steps, p.ft_steps, p.train_scenes, p.eval_scenes, p.seeds
    );

    let ft_methods = [
        TrainMethod::TernaryExact,
        TrainMethod::Lbw { bits: 4 },
        TrainMethod::Lbw { bits: 6 },
        TrainMethod::Dorefa { bits: 6 },
    ];

    let mut rows: Vec<TrainRow> = Vec::new();
    for &seed in p.seeds {
        let cfg = base_cfg(&p, seed);

        // 1. float pretraining
        let float_trainer =
            HermeticTrainer::new(cfg.clone(), p.width, TrainMethod::Float)?.with_batch(p.batch);
        let t0 = Instant::now();
        let float_out = float_trainer.train()?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "[seed {seed}] float: mAP {:.4} loss {:.3} -> {:.3} ({wall:.1}s)",
            float_out.outcome.final_map, float_out.loss_first, float_out.loss_last
        );
        rows.push(row(
            &p,
            "float",
            32,
            seed,
            p.float_steps,
            float_out.outcome.final_map,
            float_out.quant_dist,
            float_out.sparsity,
            float_out.loss_first,
            float_out.loss_last,
            wall,
        ));
        let float_ckpt = float_out.outcome.checkpoint;

        // 2. quantize + retrain per projection method
        for method in ft_methods {
            let trainer =
                HermeticTrainer::new(cfg.clone(), p.width, method)?.with_batch(p.batch);
            let t0 = Instant::now();
            let out = trainer.train_from(&float_ckpt, p.ft_steps, p.ft_lr, p.float_steps)?;
            let wall = t0.elapsed().as_secs_f64();
            println!(
                "[seed {seed}] {}: mAP {:.4} dist {:.2} sparsity {:.3} ({wall:.1}s)",
                method.name(),
                out.outcome.final_map,
                out.quant_dist,
                out.sparsity
            );
            rows.push(row(
                &p,
                &method.name(),
                method.bits(),
                seed,
                p.ft_steps,
                out.outcome.final_map,
                out.quant_dist,
                out.sparsity,
                out.loss_first,
                out.loss_last,
                wall,
            ));
        }

        // 3. INQ partitioned freezing (retrains the float shadows)
        let t0 = Instant::now();
        let inq = train_inq_hermetic(
            &float_trainer,
            6,
            &INQ_PHASES,
            &float_ckpt,
            p.ft_steps,
            p.ft_lr,
            p.float_steps,
        )?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "[seed {seed}] inq-6: mAP {:.4} dist {:.2} phases {:?} ({wall:.1}s)",
            inq.final_map,
            inq.quant_dist,
            inq.phases.iter().map(|ph| ph.frozen_total).collect::<Vec<_>>()
        );
        rows.push(row(
            &p,
            "inq-6",
            6,
            seed,
            p.ft_steps,
            inq.final_map,
            inq.quant_dist,
            inq.sparsity,
            inq.loss_first,
            inq.loss_last,
            wall,
        ));
    }

    // summary: mean mAP per method across seeds
    println!("\n== accuracy trajectory (mean mAP over {} seeds) ==", p.seeds.len());
    let mut methods: Vec<String> = Vec::new();
    for r in &rows {
        if !methods.contains(&r.method) {
            methods.push(r.method.clone());
        }
    }
    for m in &methods {
        let maps: Vec<f64> =
            rows.iter().filter(|r| &r.method == m).map(|r| r.map).collect();
        let mean = maps.iter().sum::<f64>() / maps.len() as f64;
        let r0 = rows.iter().find(|r| &r.method == m).unwrap();
        println!(
            "  {m:>13}  bits {:>2}  mAP {mean:.4}  compression {:.1}x",
            r0.bits, r0.compression
        );
    }

    let out = Path::new("BENCH_train.json");
    write_bench_train(out, p.name, &rows)?;
    println!("\nwrote {} ({} rows)", out.display(), rows.len());
    Ok(())
}
