//! Quickstart: the smallest end-to-end tour of the public API.
//!
//! 1. open the PJRT runtime over the AOT artifacts,
//! 2. train a 6-bit LBW detector for a handful of steps,
//! 3. run detection on a fresh SynthVOC scene,
//! 4. quantize one layer by hand and inspect its structure.
//!
//! Run with: `cargo run --release --example quickstart`

use anyhow::Result;
use lbw_net::coordinator::trainer::{TrainConfig, Trainer};
use lbw_net::data::{generate_scene, SceneConfig, ShapeClass};
use lbw_net::detection::{decode_grid, nms};
use lbw_net::quant::threshold::lbw_quantize_layer;
use lbw_net::runtime::{lit_f32, to_f32, Runtime};

fn main() -> Result<()> {
    // --- 1. runtime ---------------------------------------------------
    let rt = Runtime::open_default()?;
    println!("PJRT platform: {}", rt.platform());

    // --- 2. a tiny training run (60 steps, 6-bit weights) -------------
    let cfg = TrainConfig {
        bits: 6,
        steps: 60,
        train_scenes: 128,
        eval_scenes: 32,
        log_every: 20,
        ..Default::default()
    };
    let trainer = Trainer::new(&rt, cfg)?;
    let outcome = trainer.train()?;
    println!(
        "trained 60 steps: loss {:.3} -> {:.3}, mAP {:.3}",
        outcome.history.first().unwrap().loss,
        outcome.history.last().unwrap().loss,
        outcome.final_map
    );

    // --- 3. detect on a fresh scene ------------------------------------
    let ck = &outcome.checkpoint;
    let scene = generate_scene(4242, 0, &SceneConfig::default());
    let infer = rt.load("infer_a_b6_bs1")?;
    let out = infer.run(&[
        lit_f32(&ck.params, &[ck.params.len()])?,
        lit_f32(&ck.state, &[ck.state.len()])?,
        lit_f32(&scene.image, &[1, 64, 64, 3])?,
    ])?;
    let dets = nms(decode_grid(&to_f32(&out[0])?, &to_f32(&out[1])?, 0.3), 0.45);
    println!("\nscene has {} objects:", scene.objects.len());
    for g in &scene.objects {
        println!("  GT  {:>9} at ({:.0},{:.0})", ShapeClass::from_index(g.class).name(), g.bbox.x1, g.bbox.y1);
    }
    for d in &dets {
        println!(
            "  DET {:>9} score {:.2} at ({:.0},{:.0})",
            ShapeClass::from_index(d.class).name(),
            d.score,
            d.bbox.x1,
            d.bbox.y1
        );
    }

    // --- 4. quantize one layer by hand ---------------------------------
    let e = trainer.spec.param("s2.b0.conv2.w")?;
    let w = &ck.params[e.offset..e.offset + e.size];
    let q = lbw_quantize_layer(w, 6, 0.75);
    println!(
        "\nlayer s2.b0.conv2.w: {} weights -> scale 2^{}, {:.1}% zeros, {} levels used",
        w.len(),
        q.s,
        q.sparsity() * 100.0,
        q.level_counts(6).iter().filter(|&&k| k > 0).count()
    );
    Ok(())
}
