//! Serving load generator: sweep executor × engine × shard count ×
//! intra-op threads × batch window over SynthVOC scenes and record the
//! throughput/latency trajectory — plus an adaptive-vs-fixed window
//! comparison under open-loop steady and bursty load.
//!
//! Fully hermetic — the sweep drives the pure-Rust engines behind the
//! sharded server on a synthetic He-initialized detector, so it runs
//! on a clean checkout (no Python, no artifacts). Emits
//! `BENCH_serve.json`: one row per (executor, engine, shards, threads,
//! batch window) cell with wall time, img/s, latency percentiles, mean
//! batch occupancy, and the per-shard request counts. The `executor`
//! field distinguishes the planned arena executor (production path)
//! from the naive per-op reference; the `threads` field is the
//! per-shard tile-pool width (planned executor only — the naive walk
//! is always single-threaded). The summary prints the planned/naive
//! img/s ratio and the planned 4-thread/1-thread speedup per engine at
//! a single shard.
//!
//! Since the adaptive-window PR every row also carries `"window"`
//! (`"fixed"` for the classic closed-loop sweep), and an extra
//! open-loop sweep drives window ∈ {fixed-2ms, adaptive(max 10ms)} ×
//! load ∈ {steady, bursty} through one planned shift6 shard — those
//! rows additionally carry `"load"` and the merged `"shed"` counter.
//! The summary quotes bursty mean-batch occupancy (adaptive vs
//! fixed-2ms) and steady p95 (adaptive must not lose).
//!
//! Since the elastic-autoscaling PR an **autoscale sweep** drives the
//! same open-loop bursty schedule through a fixed single shard and an
//! elastic pool bounded [1, 4]: the elastic row carries
//! `"shards": "auto"` plus `"shards_max"`, `"scale_ups"`, and
//! `"scale_downs"` (the supervisor must both spawn under bursts and
//! drain in the gaps), and its `"shard_counts"` lists every shard
//! generation that ever lived. The summary quotes elastic p95 vs the
//! fixed single shard (elastic must not lose).
//!
//! Since the trained-checkpoint PR every row also carries
//! `"checkpoint"` (`"synth"` for the He-init synthetic checkpoint) and
//! one extra closed-loop cell serves a checkpoint produced by a short
//! hermetic training run (`"checkpoint": "trained"`) — the gate's
//! baselines stay on the synth rows.
//!
//! Since the fault-domain PR a **fault sweep** re-runs the planned
//! shift6 single-shard closed loop fault-free and under a seeded panic
//! storm (`seed=11;panic@pre:nth=3,every=5,...`) with retry-enabled
//! clients: those two rows carry `"faults"` (`"none"`/`"storm"`) plus
//! `"crashes"`, `"respawns"`, and `"lost"`. The gate fails any row
//! with `crashes > 0` and `lost > 0` (a crash must never cost a
//! response) or crashes without respawns; rows carrying a `"faults"`
//! marker sit outside the healthy closed-loop baselines.
//!
//! Since the SIMD-kernel PR every row also carries `"simd"`
//! (`"on"` when the serving plans used the explicit AVX2/NEON kernels,
//! `"off"` for the scalar reference — naive-executor rows are always
//! `"off"`; rows from before this PR are implicitly `"off"`), and two
//! extra closed-loop cells re-run the planned float/shift6 single-
//! shard single-thread config with the backend forced `off`, so the
//! simd/scalar ratio `scripts/bench_gate.py` gates on is measured
//! through the identical serving stack. The summary prints that ratio
//! per engine.
//!
//! Since the multi-model PR a **registry sweep** drives two cells
//! through a `ModelRegistry`: a mixed-tenant cell (6-bit + 2-bit
//! models behind one apportioned shard budget, tenant shares 3:1)
//! whose row carries `"models"`, `"tenant_mix"`, `"tenant_counts"`,
//! `"tenant_p95_ms"`, and `"resident_weight_bytes"`, and a
//! hot-swap-under-load cell whose row carries `"swaps"` and `"lost"`.
//! The gate fails a swap row that lost a request and a tenant row
//! with a starved tenant; rows carrying `"models"` sit outside the
//! single-model closed-loop baselines.
//!
//! Run with: `cargo run --release --example bench_serve`
//! Smoke mode (CI): `cargo run --release --example bench_serve -- --smoke`
//! (reduced request count + 1-shard cells only; also honours the
//! `BENCH_SERVE_REQUESTS` env var).

use std::time::{Duration, Instant};

use anyhow::Result;
use lbw_net::coordinator::autoscale::AutoscaleConfig;
use lbw_net::coordinator::server::{
    DetectServer, Executor, FaultPlan, RetryPolicy, ServerConfig, WindowMode,
};
use lbw_net::coordinator::metrics::LatencyStats;
use lbw_net::coordinator::registry::{resident_weight_bytes, ModelDef, ModelRegistry};
use lbw_net::coordinator::trainer::{HermeticTrainer, TrainConfig, TrainMethod};
use lbw_net::data::{generate_scene, SceneConfig};
use lbw_net::nn::synth::{synthetic_checkpoint, synthetic_spec, SynthConfig};
use lbw_net::nn::{EngineKind, KernelBackend, SimdMode};
use lbw_net::util::json::Json;

const CONCURRENCY: usize = 8;

struct Cell {
    executor: String,
    engine: String,
    shards: usize,
    threads: usize,
    /// Window policy: "fixed" (classic sweep) or "adaptive".
    window: String,
    /// For fixed cells the window; for adaptive cells the max window.
    window_ms: u64,
    /// Open-loop load shape ("steady"/"bursty"); None for the classic
    /// closed-loop sweep.
    load: Option<String>,
    shed: u64,
    /// Elastic cell: `shards` is the initial count and the JSON row
    /// carries `"shards": "auto"` plus the scale-event counters.
    auto: Option<AutoCell>,
    /// Where the served weights came from: "synth" (He-init synthetic
    /// checkpoint) or "trained" (a hermetic training run).
    checkpoint: &'static str,
    /// Kernel backend the serving plans ran: "on" (explicit AVX2/NEON
    /// kernels) or "off" (scalar reference; always "off" for the naive
    /// executor, which has no planned kernels).
    simd: &'static str,
    /// Fault-sweep cell: `Some` marks the chaos rows (`"storm"` under
    /// the injected panic schedule, `"none"` for the fault-free twin);
    /// rows without the field predate or sit outside the fault sweep.
    faults: Option<FaultCell>,
    /// Multi-model registry cell: `Some` marks rows driven through a
    /// `ModelRegistry` (tenant mix and/or hot swap); such rows carry a
    /// `"models"` field and sit outside the closed-loop baselines.
    multi: Option<MultiCell>,
    wall_s: f64,
    imgs_per_s: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    mean_batch: f64,
    shard_counts: Vec<usize>,
}

/// The elastic dimensions of an autoscale cell.
struct AutoCell {
    shards_max: usize,
    scale_ups: u64,
    scale_downs: u64,
}

/// The fault dimensions of a chaos cell. `lost` counts closed-loop
/// requests whose client got an error back instead of detections —
/// under the crash storm every panic is caught, the batch is bisected,
/// and the generation respawns, so a healthy fault domain answers
/// every request (`lost == 0` is what `scripts/bench_gate.py` gates).
struct FaultCell {
    spec: &'static str,
    crashes: u64,
    respawns: u64,
    lost: u64,
}

/// The multi-model registry dimensions. Every registry row carries
/// `"models"` — `scripts/bench_gate.py` keeps such rows out of the
/// single-model closed-loop baselines and instead enforces the tenant
/// and swap rules on them.
struct MultiCell {
    /// The registry roster, e.g. `"hi=shift6+lo=shift2"`.
    models: String,
    /// Total resident quantized weight bytes across the registry — the
    /// LBW packing story measured, not asserted.
    resident_bytes: usize,
    /// Weighted-fair cell: the tenant share spec (e.g. `"3:1"`) plus
    /// per-tenant dequeue counts and client-side p95, both merged
    /// across every model cell in the registry.
    tenant_mix: Option<String>,
    tenant_counts: Vec<u64>,
    tenant_p95_ms: Vec<f64>,
    /// Hot-swap cell: checkpoint swaps landed mid-run, and closed-loop
    /// requests whose client got an error back — the gate fails any
    /// swap row with `lost > 0` (a swap must never cost a response).
    swaps: Option<u64>,
    lost: Option<u64>,
}

fn drive(server: &DetectServer, scenes: &[Vec<f32>], requests: usize) -> Result<Duration> {
    let handle = server.handle();
    let t0 = Instant::now();
    let per = requests / CONCURRENCY;
    let mut clients = Vec::new();
    for c in 0..CONCURRENCY {
        let h = handle.clone();
        let imgs: Vec<Vec<f32>> =
            (0..per).map(|i| scenes[(c * per + i) % scenes.len()].clone()).collect();
        clients.push(std::thread::spawn(move || -> Result<()> {
            for img in imgs {
                h.detect(img)?;
            }
            Ok(())
        }));
    }
    for c in clients {
        c.join().expect("client thread")?;
    }
    Ok(t0.elapsed())
}

/// Open-loop driver: every request fires at its scheduled offset from
/// the start, whether or not earlier ones have completed — the
/// arrival process is independent of service times, like real traffic.
/// Returns (wall, requests that got an error, e.g. shed).
fn drive_open_loop(
    server: &DetectServer,
    scenes: &[Vec<f32>],
    offsets: &[Duration],
) -> (Duration, usize) {
    let handle = server.handle();
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for (i, &off) in offsets.iter().enumerate() {
        let h = handle.clone();
        let img = scenes[i % scenes.len()].clone();
        clients.push(std::thread::spawn(move || {
            std::thread::sleep(off.saturating_sub(t0.elapsed()));
            h.detect(img).is_err()
        }));
    }
    let mut errors = 0usize;
    for c in clients {
        if c.join().expect("open-loop client") {
            errors += 1;
        }
    }
    (t0.elapsed(), errors)
}

/// `n` arrivals evenly spaced `gap` apart.
fn steady_schedule(n: usize, gap: Duration) -> Vec<Duration> {
    (0..n).map(|i| gap * i as u32).collect()
}

/// `n` arrivals in bursts of `burst`: `intra` apart inside a burst,
/// burst heads `period` apart.
fn bursty_schedule(n: usize, burst: usize, intra: Duration, period: Duration) -> Vec<Duration> {
    (0..n).map(|i| period * (i / burst) as u32 + intra * (i % burst) as u32).collect()
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let requests: usize = std::env::var("BENCH_SERVE_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 48 } else { 192 });
    let shard_list: &[usize] = if smoke { &[1] } else { &[1, 2, 4] };
    let window_list: &[u64] = if smoke { &[2] } else { &[0, 2] };

    // what the planned executor's plans will actually run under the
    // default SimdMode — recorded on every planned cell
    let detected: &'static str =
        if KernelBackend::detect(SimdMode::from_env()).is_simd() { "on" } else { "off" };

    let spec = synthetic_spec(SynthConfig::default());
    let ckpt = synthetic_checkpoint(&spec, 2027, 6);
    let scene_cfg = SceneConfig::default();
    let scenes: Vec<Vec<f32>> =
        (0..32u64).map(|i| generate_scene(4242, i, &scene_cfg).image).collect();

    println!(
        "=== bench_serve: {requests} requests, {CONCURRENCY} clients, synthetic detector{} ===",
        if smoke { " (smoke)" } else { "" }
    );
    println!(
        "{:<9} {:<8} {:<7} {:<8} {:<10} {:>9} {:>9} {:>9} {:>9} {:>11}",
        "executor", "engine", "shards", "threads", "window", "img/s", "p50 ms", "p95 ms",
        "p99 ms", "mean batch"
    );

    let mut cells: Vec<Cell> = Vec::new();
    for (exec_name, executor) in [("planned", Executor::Planned), ("naive", Executor::Naive)] {
        // the tile pool only feeds the planned executor's kernels; the
        // naive walk is single-threaded by construction
        let thread_list: &[usize] = match executor {
            Executor::Planned => &[1, 4],
            Executor::Naive => &[1],
        };
        for (engine_name, engine) in
            [("float", EngineKind::Float), ("shift6", EngineKind::Shift { bits: 6 })]
        {
            for &shards in shard_list {
                for &threads in thread_list {
                    for &window_ms in window_list {
                        let cfg = ServerConfig {
                            shards,
                            threads,
                            max_batch: 8,
                            batch_window: Duration::from_millis(window_ms),
                            queue_depth: 256,
                            executor,
                            // sweep cells must stay fault-free even when
                            // the chaos CI leg exports LBW_FAULTS
                            faults: None,
                            ..Default::default()
                        };
                        let server = DetectServer::start_engine(&spec, &ckpt, engine, cfg)?;
                        let wall = drive(&server, &scenes, requests)?;
                        let agg = server.handle().latency();
                        let snap = agg.snapshot();
                        let shard_counts: Vec<usize> =
                            server.shard_latencies().iter().map(|s| s.count()).collect();
                        let cell = Cell {
                            executor: exec_name.to_string(),
                            engine: engine_name.to_string(),
                            shards,
                            threads,
                            window: "fixed".to_string(),
                            window_ms,
                            load: None,
                            shed: 0,
                            auto: None,
                            checkpoint: "synth",
                            simd: match executor {
                                Executor::Planned => detected,
                                Executor::Naive => "off",
                            },
                            faults: None,
                            multi: None,
                            wall_s: wall.as_secs_f64(),
                            imgs_per_s: agg.throughput(wall),
                            p50_ms: snap.percentile_ms(50.0),
                            p95_ms: snap.percentile_ms(95.0),
                            p99_ms: snap.percentile_ms(99.0),
                            mean_batch: agg.mean_batch(),
                            shard_counts,
                        };
                        println!(
                            "{:<9} {:<8} {:<7} {:<8} {:<10} {:>9.1} {:>9.2} {:>9.2} {:>9.2} {:>11.2}",
                            cell.executor,
                            cell.engine,
                            cell.shards,
                            cell.threads,
                            format!("{window_ms}ms"),
                            cell.imgs_per_s,
                            cell.p50_ms,
                            cell.p95_ms,
                            cell.p99_ms,
                            cell.mean_batch
                        );
                        server.shutdown();
                        cells.push(cell);
                    }
                }
            }
        }
    }

    // ---- forced-scalar baseline cells (closed loop) ----
    // the planned float/shift6 single-shard single-thread configs
    // re-run with the kernel backend forced off — the scalar
    // denominator of the simd/scalar ratio the bench gate enforces,
    // measured through the identical serving stack. Only meaningful
    // (and only run) when the detected backend is actually SIMD;
    // without it the sweep above already produced these exact rows.
    if detected == "on" {
        println!("\n--- forced-scalar cells (simd off): planned, 1 shard x 1 thread ---");
        for (engine_name, engine) in
            [("float", EngineKind::Float), ("shift6", EngineKind::Shift { bits: 6 })]
        {
            let cfg = ServerConfig {
                shards: 1,
                threads: 1,
                max_batch: 8,
                batch_window: Duration::from_millis(2),
                queue_depth: 256,
                executor: Executor::Planned,
                simd: SimdMode::Off,
                faults: None,
                ..Default::default()
            };
            let server = DetectServer::start_engine(&spec, &ckpt, engine, cfg)?;
            let wall = drive(&server, &scenes, requests)?;
            let agg = server.handle().latency();
            let snap = agg.snapshot();
            let shard_counts: Vec<usize> =
                server.shard_latencies().iter().map(|s| s.count()).collect();
            let cell = Cell {
                executor: "planned".to_string(),
                engine: engine_name.to_string(),
                shards: 1,
                threads: 1,
                window: "fixed".to_string(),
                window_ms: 2,
                load: None,
                shed: 0,
                auto: None,
                checkpoint: "synth",
                simd: "off",
                faults: None,
                multi: None,
                wall_s: wall.as_secs_f64(),
                imgs_per_s: agg.throughput(wall),
                p50_ms: snap.percentile_ms(50.0),
                p95_ms: snap.percentile_ms(95.0),
                p99_ms: snap.percentile_ms(99.0),
                mean_batch: agg.mean_batch(),
                shard_counts,
            };
            println!(
                "{:<9} {:<8} {:<7} {:<8} {:<10} {:>9.1} {:>9.2} {:>9.2} {:>9.2} {:>11.2}  (simd off)",
                cell.executor,
                cell.engine,
                cell.shards,
                cell.threads,
                "2ms",
                cell.imgs_per_s,
                cell.p50_ms,
                cell.p95_ms,
                cell.p99_ms,
                cell.mean_batch
            );
            server.shutdown();
            cells.push(cell);
        }
    }

    // ---- adaptive-vs-fixed window sweep (open-loop load) ----
    // one planned shift6 shard; "fixed" is the classic 2ms window,
    // "adaptive" lets the load observer pick within [0, 10ms]. The
    // offered load (~160 req/s both shapes) stays under engine
    // capacity on purpose: a saturated queue batches fully under ANY
    // policy, so the comparison would measure saturation, not the
    // window controller.
    println!("\n--- window sweep (open-loop): planned shift6, 1 shard ---");
    let steady_gap = Duration::from_millis(6);
    let burst = 16usize;
    let window_cells: &[(&str, WindowMode, u64)] =
        &[("fixed", WindowMode::Fixed, 2), ("adaptive", WindowMode::Adaptive, 10)];
    for &(win_name, window, window_ms) in window_cells {
        for load in ["steady", "bursty"] {
            let offsets = match load {
                "steady" => steady_schedule(requests, steady_gap),
                _ => bursty_schedule(
                    requests,
                    burst,
                    Duration::from_millis(1),
                    Duration::from_millis(100),
                ),
            };
            let cfg = ServerConfig {
                shards: 1,
                threads: 1,
                max_batch: 8,
                batch_window: Duration::from_millis(window_ms),
                window,
                // generous admission deadline: healthy runs shed
                // nothing (nominal p99 is ~10x lower), but every
                // request runs the stamp + expiry check, so a
                // false-shedding regression shows up as nonzero
                // "shed"/errors in these rows
                deadline: Some(Duration::from_millis(250)),
                queue_depth: 256,
                executor: Executor::Planned,
                faults: None,
                ..Default::default()
            };
            let server =
                DetectServer::start_engine(&spec, &ckpt, EngineKind::Shift { bits: 6 }, cfg)?;
            let (wall, errors) = drive_open_loop(&server, &scenes, &offsets);
            let agg = server.handle().latency();
            let snap = agg.snapshot();
            let shard_counts: Vec<usize> =
                server.shard_latencies().iter().map(|s| s.count()).collect();
            let cell = Cell {
                executor: "planned".to_string(),
                engine: "shift6".to_string(),
                shards: 1,
                threads: 1,
                window: win_name.to_string(),
                window_ms,
                load: Some(load.to_string()),
                shed: agg.shed(),
                auto: None,
                checkpoint: "synth",
                simd: detected,
                faults: None,
                multi: None,
                wall_s: wall.as_secs_f64(),
                imgs_per_s: agg.throughput(wall),
                p50_ms: snap.percentile_ms(50.0),
                p95_ms: snap.percentile_ms(95.0),
                p99_ms: snap.percentile_ms(99.0),
                mean_batch: agg.mean_batch(),
                shard_counts,
            };
            println!(
                "{:<9} {:<8} {:<7} {:<8} {:<10} {:>9.1} {:>9.2} {:>9.2} {:>9.2} {:>11.2}  ({load}, errors {errors})",
                cell.executor,
                cell.engine,
                cell.shards,
                cell.threads,
                win_name,
                cell.imgs_per_s,
                cell.p50_ms,
                cell.p95_ms,
                cell.p99_ms,
                cell.mean_batch
            );
            server.shutdown();
            cells.push(cell);
        }
    }
    // the adaptive-window acceptance numbers: occupancy must win under
    // bursts, p95 must not lose under steady light load
    let open = |win: &str, load: &str| {
        cells.iter().find(|c| c.window == win && c.load.as_deref() == Some(load))
    };
    if let (Some(af), Some(ff)) = (open("adaptive", "bursty"), open("fixed", "bursty")) {
        println!(
            "bursty: adaptive mean batch {:.2} vs fixed-2ms {:.2} ({:+.0}%)",
            af.mean_batch,
            ff.mean_batch,
            100.0 * (af.mean_batch / ff.mean_batch - 1.0)
        );
    }
    if let (Some(a), Some(f)) = (open("adaptive", "steady"), open("fixed", "steady")) {
        println!("steady: adaptive p95 {:.2}ms vs fixed-2ms p95 {:.2}ms", a.p95_ms, f.p95_ms);
    }

    // ---- autoscale sweep (open-loop bursty) ----
    // same engine/executor, same bursty schedule, two servers: a fixed
    // single shard vs an elastic pool [1, 4]. Bursts land all at once
    // (intra 0) so the queue-depth spike is load-shaped, not
    // engine-speed-shaped; the ~100ms inter-burst gaps are long enough
    // for the supervisor's idle law to drain back down — each run
    // should show scale-ups during bursts AND drains between them,
    // with p95 no worse than the fixed shard (the elastic pool eats
    // the burst tail faster).
    println!("\n--- autoscale sweep (open-loop bursty): planned shift6 ---");
    let auto_offsets =
        bursty_schedule(requests, burst, Duration::ZERO, Duration::from_millis(100));
    let mut fixed_1shard_p95 = 0.0f64;
    for elastic in [false, true] {
        let cfg = ServerConfig {
            shards: 1,
            threads: 1,
            max_batch: 8,
            batch_window: Duration::from_millis(2),
            queue_depth: 256,
            executor: Executor::Planned,
            autoscale: elastic.then(|| AutoscaleConfig {
                min_shards: 1,
                max_shards: 4,
                tick: Duration::from_millis(2),
                cooldown_ticks: 2,
                down_idle_ticks: 10,
                ..AutoscaleConfig::default()
            }),
            faults: None,
            ..Default::default()
        };
        let server =
            DetectServer::start_engine(&spec, &ckpt, EngineKind::Shift { bits: 6 }, cfg)?;
        let (wall, errors) = drive_open_loop(&server, &scenes, &auto_offsets);
        let agg = server.handle().latency();
        let snap = agg.snapshot();
        let shard_counts: Vec<usize> =
            server.shard_latencies().iter().map(|s| s.count()).collect();
        let (ups, downs) = server.scale_events();
        let cell = Cell {
            executor: "planned".to_string(),
            engine: "shift6".to_string(),
            shards: 1,
            threads: 1,
            window: "fixed".to_string(),
            window_ms: 2,
            load: Some("bursty".to_string()),
            shed: agg.shed(),
            auto: elastic.then(|| AutoCell { shards_max: 4, scale_ups: ups, scale_downs: downs }),
            checkpoint: "synth",
            simd: detected,
            faults: None,
            multi: None,
            wall_s: wall.as_secs_f64(),
            imgs_per_s: agg.throughput(wall),
            p50_ms: snap.percentile_ms(50.0),
            p95_ms: snap.percentile_ms(95.0),
            p99_ms: snap.percentile_ms(99.0),
            mean_batch: agg.mean_batch(),
            shard_counts,
        };
        println!(
            "{:<9} {:<8} {:<7} {:<8} {:<10} {:>9.1} {:>9.2} {:>9.2} {:>9.2} {:>11.2}  (bursty, errors {errors}, ups {ups}, drains {downs})",
            cell.executor,
            cell.engine,
            if elastic { "auto".to_string() } else { "1".to_string() },
            cell.threads,
            "2ms",
            cell.imgs_per_s,
            cell.p50_ms,
            cell.p95_ms,
            cell.p99_ms,
            cell.mean_batch
        );
        if !elastic {
            fixed_1shard_p95 = cell.p95_ms;
        }
        server.shutdown();
        cells.push(cell);
    }
    if let Some(a) = cells.iter().find(|c| c.auto.is_some()) {
        let e = a.auto.as_ref().expect("auto cell");
        println!(
            "autoscale bursty: p95 {:.2}ms vs fixed-1shard {:.2}ms, {} scale-up(s) / {} drain(s) across {} shard generation(s)",
            a.p95_ms, fixed_1shard_p95, e.scale_ups, e.scale_downs, a.shard_counts.len()
        );
    }

    // ---- trained-checkpoint cell ----
    // the same planned shift6 single-shard closed loop, but serving a
    // checkpoint a short hermetic training run produced instead of the
    // He-init synthetic one — proof the serving stack consumes real
    // trainer output, and a throughput cross-check that trained weight
    // statistics (lower variance, more pruned-to-zero after LBW) do
    // not regress the shift engine. `checkpoint: "trained"` keeps the
    // gate's closed-loop baselines on the synth rows.
    println!("\n--- trained-checkpoint cell: planned shift6, 1 shard ---");
    let train_cfg = TrainConfig {
        seed: 2027,
        steps: if smoke { 30 } else { 120 },
        lr: 0.05,
        train_scenes: 64,
        eval_scenes: 8,
        log_every: 0,
        ..Default::default()
    };
    let trained = HermeticTrainer::new(train_cfg, 8, TrainMethod::Float)?
        .train()?
        .outcome
        .checkpoint;
    {
        let cfg = ServerConfig {
            shards: 1,
            threads: 1,
            max_batch: 8,
            batch_window: Duration::from_millis(2),
            queue_depth: 256,
            executor: Executor::Planned,
            faults: None,
            ..Default::default()
        };
        let server =
            DetectServer::start_engine(&spec, &trained, EngineKind::Shift { bits: 6 }, cfg)?;
        let wall = drive(&server, &scenes, requests)?;
        let agg = server.handle().latency();
        let snap = agg.snapshot();
        let shard_counts: Vec<usize> =
            server.shard_latencies().iter().map(|s| s.count()).collect();
        let cell = Cell {
            executor: "planned".to_string(),
            engine: "shift6".to_string(),
            shards: 1,
            threads: 1,
            window: "fixed".to_string(),
            window_ms: 2,
            load: None,
            shed: 0,
            auto: None,
            checkpoint: "trained",
            simd: detected,
            faults: None,
            multi: None,
            wall_s: wall.as_secs_f64(),
            imgs_per_s: agg.throughput(wall),
            p50_ms: snap.percentile_ms(50.0),
            p95_ms: snap.percentile_ms(95.0),
            p99_ms: snap.percentile_ms(99.0),
            mean_batch: agg.mean_batch(),
            shard_counts,
        };
        println!(
            "{:<9} {:<8} {:<7} {:<8} {:<10} {:>9.1} {:>9.2} {:>9.2} {:>9.2} {:>11.2}  (trained ckpt, step {})",
            cell.executor,
            cell.engine,
            cell.shards,
            cell.threads,
            "2ms",
            cell.imgs_per_s,
            cell.p50_ms,
            cell.p95_ms,
            cell.p99_ms,
            cell.mean_batch,
            trained.step
        );
        server.shutdown();
        cells.push(cell);
    }

    // ---- fault sweep (closed loop, injected panic storm) ----
    // the same planned shift6 single-shard closed loop twice: once
    // fault-free ("none") and once under a seeded panic schedule that
    // crashes the shard on its 3rd batch and every 5th after, per
    // generation ("storm"). Clients carry the default bounded retry.
    // A healthy fault domain turns every crash into: batch bisected
    // and answered, generation retired, replacement respawned — so the
    // storm row must show crashes > 0 with lost == 0 and bounded p95
    // inflation over the "none" twin (the gate enforces the loss rule).
    println!("\n--- fault sweep (closed loop): planned shift6, 1 shard ---");
    let storm_spec = "seed=11;panic@pre:nth=3,every=5,count=1000000";
    let mut fault_free_p95 = 0.0f64;
    for (fault_name, plan) in [("none", None), ("storm", Some(storm_spec))] {
        let cfg = ServerConfig {
            shards: 1,
            threads: 1,
            max_batch: 8,
            batch_window: Duration::from_millis(2),
            queue_depth: 256,
            executor: Executor::Planned,
            faults: plan.map(|p| FaultPlan::parse(p).expect("storm plan")),
            ..Default::default()
        };
        let server =
            DetectServer::start_engine(&spec, &ckpt, EngineKind::Shift { bits: 6 }, cfg)?;
        let handle = server.handle().with_retry(RetryPolicy::default());
        let t0 = Instant::now();
        let per = requests / CONCURRENCY;
        let mut clients = Vec::new();
        for c in 0..CONCURRENCY {
            let h = handle.clone();
            let imgs: Vec<Vec<f32>> =
                (0..per).map(|i| scenes[(c * per + i) % scenes.len()].clone()).collect();
            clients.push(std::thread::spawn(move || {
                // count errors instead of bailing: a request answered
                // with an error under the storm is a lost response
                let mut lost = 0u64;
                for img in imgs {
                    if h.detect(img).is_err() {
                        lost += 1;
                    }
                }
                lost
            }));
        }
        let lost: u64 = clients.into_iter().map(|c| c.join().expect("fault client")).sum();
        let wall = t0.elapsed();
        // a crash near the end of the run respawns asynchronously:
        // give the supervisor a beat so the row's respawn counter
        // reflects every crash it answered
        let respawn_deadline = Instant::now() + Duration::from_secs(2);
        while server.respawns() < server.crashes() && Instant::now() < respawn_deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let agg = server.handle().latency();
        let snap = agg.snapshot();
        let shard_counts: Vec<usize> =
            server.shard_latencies().iter().map(|s| s.count()).collect();
        let (crashes, respawns) = (server.crashes(), server.respawns());
        let cell = Cell {
            executor: "planned".to_string(),
            engine: "shift6".to_string(),
            shards: 1,
            threads: 1,
            window: "fixed".to_string(),
            window_ms: 2,
            load: None,
            shed: 0,
            auto: None,
            checkpoint: "synth",
            simd: detected,
            faults: Some(FaultCell { spec: fault_name, crashes, respawns, lost }),
            multi: None,
            wall_s: wall.as_secs_f64(),
            imgs_per_s: agg.throughput(wall),
            p50_ms: snap.percentile_ms(50.0),
            p95_ms: snap.percentile_ms(95.0),
            p99_ms: snap.percentile_ms(99.0),
            mean_batch: agg.mean_batch(),
            shard_counts,
        };
        println!(
            "{:<9} {:<8} {:<7} {:<8} {:<10} {:>9.1} {:>9.2} {:>9.2} {:>9.2} {:>11.2}  ({fault_name}: {crashes} crash(es), {respawns} respawn(s), lost {lost})",
            cell.executor,
            cell.engine,
            cell.shards,
            cell.threads,
            "2ms",
            cell.imgs_per_s,
            cell.p50_ms,
            cell.p95_ms,
            cell.p99_ms,
            cell.mean_batch
        );
        if fault_name == "none" {
            fault_free_p95 = cell.p95_ms;
        }
        server.shutdown();
        cells.push(cell);
    }
    if let Some(s) =
        cells.iter().find(|c| c.faults.as_ref().is_some_and(|f| f.spec == "storm"))
    {
        let f = s.faults.as_ref().expect("storm cell");
        println!(
            "fault storm: p95 {:.2}ms vs fault-free {:.2}ms ({:+.0}%), {} crash(es) -> {} respawn(s), lost {}",
            s.p95_ms,
            fault_free_p95,
            if fault_free_p95 > 0.0 { 100.0 * (s.p95_ms / fault_free_p95 - 1.0) } else { 0.0 },
            f.crashes,
            f.respawns,
            f.lost
        );
    }

    // ---- multi-model multi-tenant cell (closed loop) ----
    // one ModelRegistry serving a 6-bit and a 2-bit model behind one
    // apportioned shard budget, with two weighted-fair tenant classes
    // (shares 3:1). Clients split across model x tenant; the row
    // records per-tenant dequeue counts and client-side p95 (merged
    // across both model cells) plus the registry's total resident
    // quantized weight bytes — the LBW packing story: both models
    // together occupy a fraction of one float model's weights. The
    // gate fails the row if any listed tenant saw zero dequeues.
    println!("\n--- multi-model tenant cell: registry hi=shift6 + lo=shift2, tenants 3:1 ---");
    {
        let base = ServerConfig {
            shards: 2, // apportioned: one per model
            threads: 1,
            max_batch: 8,
            batch_window: Duration::from_millis(2),
            queue_depth: 256,
            executor: Executor::Planned,
            tenants: vec![3, 1],
            faults: None,
            ..Default::default()
        };
        let defs = vec![
            ModelDef {
                name: "hi".into(),
                spec: spec.clone(),
                ckpt: ckpt.clone(),
                engine: EngineKind::Shift { bits: 6 },
            },
            ModelDef {
                name: "lo".into(),
                spec: spec.clone(),
                ckpt: synthetic_checkpoint(&spec, 2027, 2),
                engine: EngineKind::Shift { bits: 2 },
            },
        ];
        let registry = ModelRegistry::start(defs, &base)?;
        let router = registry.router();
        let t0 = Instant::now();
        let per = requests / CONCURRENCY;
        let names = ["hi", "lo"];
        let mut clients = Vec::new();
        for c in 0..CONCURRENCY {
            let r = router.clone();
            let imgs: Vec<Vec<f32>> =
                (0..per).map(|i| scenes[(c * per + i) % scenes.len()].clone()).collect();
            let model = names[c % names.len()];
            let tenant = c % 2;
            clients.push(std::thread::spawn(move || -> Result<()> {
                for img in imgs {
                    r.detect(model, tenant, img)?;
                }
                Ok(())
            }));
        }
        for c in clients {
            c.join().expect("tenant client")?;
        }
        let wall = t0.elapsed();
        let mut agg = LatencyStats::new();
        let mut tenant_stats = vec![LatencyStats::new(); 2];
        let mut tenant_counts = vec![0u64; 2];
        let mut shard_counts: Vec<usize> = Vec::new();
        for m in names {
            let cell = registry.server(m)?;
            agg.merge(&cell.handle().latency());
            for (t, s) in cell.tenant_latencies().iter().enumerate() {
                tenant_stats[t].merge(s);
            }
            for (t, &n) in cell.tenant_served().iter().enumerate() {
                tenant_counts[t] += n;
            }
            shard_counts.extend(cell.shard_latencies().iter().map(|s| s.count()));
        }
        let snap = agg.snapshot();
        let tenant_p95_ms: Vec<f64> =
            tenant_stats.iter().map(|s| s.percentile_ms(95.0)).collect();
        let resident = registry.total_resident_bytes();
        println!(
            "resident weights: hi {} B (6-bit) + lo {} B (2-bit) = {} B vs one float model {} B",
            registry.resident_bytes("hi")?,
            registry.resident_bytes("lo")?,
            resident,
            resident_weight_bytes(spec.num_params, EngineKind::Float)
        );
        let cell = Cell {
            executor: "planned".to_string(),
            engine: "multi".to_string(),
            shards: 2,
            threads: 1,
            window: "fixed".to_string(),
            window_ms: 2,
            load: None,
            shed: 0,
            auto: None,
            checkpoint: "synth",
            simd: detected,
            faults: None,
            multi: Some(MultiCell {
                models: "hi=shift6+lo=shift2".to_string(),
                resident_bytes: resident,
                tenant_mix: Some("3:1".to_string()),
                tenant_counts: tenant_counts.clone(),
                tenant_p95_ms: tenant_p95_ms.clone(),
                swaps: None,
                lost: None,
            }),
            wall_s: wall.as_secs_f64(),
            imgs_per_s: agg.throughput(wall),
            p50_ms: snap.percentile_ms(50.0),
            p95_ms: snap.percentile_ms(95.0),
            p99_ms: snap.percentile_ms(99.0),
            mean_batch: agg.mean_batch(),
            shard_counts,
        };
        println!(
            "{:<9} {:<8} {:<7} {:<8} {:<10} {:>9.1} {:>9.2} {:>9.2} {:>9.2} {:>11.2}  (tenants 3:1, dequeues {:?}, p95 {:?} ms)",
            cell.executor,
            cell.engine,
            cell.shards,
            cell.threads,
            "2ms",
            cell.imgs_per_s,
            cell.p50_ms,
            cell.p95_ms,
            cell.p99_ms,
            cell.mean_batch,
            tenant_counts,
            tenant_p95_ms.iter().map(|p| (p * 100.0).round() / 100.0).collect::<Vec<_>>()
        );
        drop(router);
        registry.shutdown();
        cells.push(cell);
    }

    // ---- hot-swap-under-load cell (closed loop) ----
    // one registry model, two shards, the classic closed loop — with
    // two checkpoint swaps landed while the burst is in flight. Each
    // swap loads + quantizes off the serving path, spawns a fresh
    // generation, and drains the old via the cancel-before-pop
    // handshake, so every in-flight request is answered by exactly one
    // generation: the row must show `swaps >= 1` with `lost == 0`
    // (the gate enforces both).
    println!("\n--- hot-swap-under-load cell: registry m6=shift6, 2 shards ---");
    {
        let base = ServerConfig {
            shards: 2,
            threads: 1,
            max_batch: 8,
            batch_window: Duration::from_millis(2),
            queue_depth: 256,
            executor: Executor::Planned,
            faults: None,
            ..Default::default()
        };
        let registry = ModelRegistry::start(
            vec![ModelDef {
                name: "m6".into(),
                spec: spec.clone(),
                ckpt: ckpt.clone(),
                engine: EngineKind::Shift { bits: 6 },
            }],
            &base,
        )?;
        let handle = registry.handle("m6")?;
        let t0 = Instant::now();
        let per = requests / CONCURRENCY;
        let mut clients = Vec::new();
        for c in 0..CONCURRENCY {
            let h = handle.clone();
            let imgs: Vec<Vec<f32>> =
                (0..per).map(|i| scenes[(c * per + i) % scenes.len()].clone()).collect();
            clients.push(std::thread::spawn(move || {
                // count errors instead of bailing: a request answered
                // with an error across a swap is a lost response
                let mut lost = 0u64;
                for img in imgs {
                    if h.detect(img).is_err() {
                        lost += 1;
                    }
                }
                lost
            }));
        }
        let mut swaps = 0u64;
        for _ in 0..2 {
            std::thread::sleep(Duration::from_millis(5));
            registry.swap("m6", &ckpt)?;
            swaps += 1;
        }
        let lost: u64 = clients.into_iter().map(|c| c.join().expect("swap client")).sum();
        let wall = t0.elapsed();
        let cell_srv = registry.server("m6")?;
        let agg = cell_srv.handle().latency();
        let snap = agg.snapshot();
        let shard_counts: Vec<usize> =
            cell_srv.shard_latencies().iter().map(|s| s.count()).collect();
        let resident = registry.total_resident_bytes();
        let cell = Cell {
            executor: "planned".to_string(),
            engine: "shift6".to_string(),
            shards: 2,
            threads: 1,
            window: "fixed".to_string(),
            window_ms: 2,
            load: None,
            shed: 0,
            auto: None,
            checkpoint: "synth",
            simd: detected,
            faults: None,
            multi: Some(MultiCell {
                models: "m6=shift6".to_string(),
                resident_bytes: resident,
                tenant_mix: None,
                tenant_counts: Vec::new(),
                tenant_p95_ms: Vec::new(),
                swaps: Some(swaps),
                lost: Some(lost),
            }),
            wall_s: wall.as_secs_f64(),
            imgs_per_s: agg.throughput(wall),
            p50_ms: snap.percentile_ms(50.0),
            p95_ms: snap.percentile_ms(95.0),
            p99_ms: snap.percentile_ms(99.0),
            mean_batch: agg.mean_batch(),
            shard_counts,
        };
        println!(
            "{:<9} {:<8} {:<7} {:<8} {:<10} {:>9.1} {:>9.2} {:>9.2} {:>9.2} {:>11.2}  ({swaps} hot swap(s) mid-burst, lost {lost})",
            cell.executor,
            cell.engine,
            cell.shards,
            cell.threads,
            "2ms",
            cell.imgs_per_s,
            cell.p50_ms,
            cell.p95_ms,
            cell.p99_ms,
            cell.mean_batch
        );
        drop(handle);
        registry.shutdown();
        cells.push(cell);
    }

    let rate_simd = |exec: &str, engine: &str, shards: usize, threads: usize, simd: &str| {
        cells
            .iter()
            .find(|c| {
                c.executor == exec
                    && c.engine == engine
                    && c.shards == shards
                    && c.threads == threads
                    && c.window_ms == 2
                    && c.load.is_none() // classic closed-loop cells only
                    && c.faults.is_none()
                    && c.multi.is_none()
                    && c.checkpoint == "synth"
                    && c.simd == simd
            })
            .map(|c| c.imgs_per_s)
            .unwrap_or(0.0)
    };
    // the pre-SIMD summary ratios compare cells under the *detected*
    // backend (naive rows are always scalar — the naive walk has no
    // planned kernels to vectorize)
    let rate = |exec: &str, engine: &str, shards: usize, threads: usize| {
        rate_simd(exec, engine, shards, threads, if exec == "naive" { "off" } else { detected })
    };
    // the headline ratio: planned vs naive through the identical
    // serving stack, single shard, single thread (the ISSUE-2
    // acceptance number)
    for engine in ["float", "shift6"] {
        let (p, n) = (rate("planned", engine, 1, 1), rate("naive", engine, 1, 1));
        if n > 0.0 {
            println!("{engine}: planned/naive single-shard speedup = {:.2}x", p / n);
        }
    }
    // intra-op scaling: 4-thread vs 1-thread pools at a single shard
    // (the ISSUE-3 acceptance number)
    for engine in ["float", "shift6"] {
        let (t4, t1) = (rate("planned", engine, 1, 4), rate("planned", engine, 1, 1));
        if t1 > 0.0 {
            println!(
                "{engine}: planned 4-thread/1-thread speedup at 1 shard = {:.2}x",
                t4 / t1
            );
        }
    }
    // the ISSUE-7 acceptance number: explicit SIMD vs forced-scalar
    // through the identical serving stack (only measurable when the
    // host actually has a SIMD backend)
    if detected == "on" {
        for engine in ["float", "shift6"] {
            let (on, off) =
                (rate_simd("planned", engine, 1, 1, "on"), rate_simd("planned", engine, 1, 1, "off"));
            if off > 0.0 {
                println!("{engine}: planned simd/scalar speedup at 1 shard x 1 thread = {:.2}x", on / off);
            }
        }
    }
    if !smoke {
        // scaling summary on the production path: shards=4 vs shards=1
        for engine in ["float", "shift6"] {
            let (r1, r4) = (rate("planned", engine, 1, 1), rate("planned", engine, 4, 1));
            if r1 > 0.0 {
                println!("{engine}: planned 4-shard speedup over 1 shard = {:.2}x", r4 / r1);
            }
        }
    }

    let rows = Json::Arr(
        cells
            .iter()
            .map(|c| {
                let shards_field = match &c.auto {
                    // elastic rows: shard count is a supervisor
                    // decision, not a config cell — the row records
                    // "auto" plus the bound and the scale events
                    Some(_) => Json::str("auto"),
                    None => Json::num(c.shards as f64),
                };
                let mut fields = vec![
                    ("executor", Json::str(c.executor.as_str())),
                    ("engine", Json::str(c.engine.as_str())),
                    ("shards", shards_field),
                    ("threads", Json::num(c.threads as f64)),
                    ("window", Json::str(c.window.as_str())),
                    ("batch_window_ms", Json::num(c.window_ms as f64)),
                    ("checkpoint", Json::str(c.checkpoint)),
                    ("simd", Json::str(c.simd)),
                    ("requests", Json::num(requests as f64)),
                    ("concurrency", Json::num(CONCURRENCY as f64)),
                    ("wall_s", Json::num(c.wall_s)),
                    ("imgs_per_s", Json::num(c.imgs_per_s)),
                    ("p50_ms", Json::num(c.p50_ms)),
                    ("p95_ms", Json::num(c.p95_ms)),
                    ("p99_ms", Json::num(c.p99_ms)),
                    ("mean_batch", Json::num(c.mean_batch)),
                    (
                        "shard_counts",
                        Json::Arr(c.shard_counts.iter().map(|&n| Json::num(n as f64)).collect()),
                    ),
                ];
                if let Some(load) = &c.load {
                    fields.push(("load", Json::str(load.as_str())));
                    fields.push(("shed", Json::num(c.shed as f64)));
                }
                if let Some(a) = &c.auto {
                    fields.push(("shards_max", Json::num(a.shards_max as f64)));
                    fields.push(("scale_ups", Json::num(a.scale_ups as f64)));
                    fields.push(("scale_downs", Json::num(a.scale_downs as f64)));
                }
                if let Some(f) = &c.faults {
                    fields.push(("faults", Json::str(f.spec)));
                    fields.push(("crashes", Json::num(f.crashes as f64)));
                    fields.push(("respawns", Json::num(f.respawns as f64)));
                    fields.push(("lost", Json::num(f.lost as f64)));
                }
                if let Some(m) = &c.multi {
                    fields.push(("models", Json::str(m.models.as_str())));
                    fields.push(("resident_weight_bytes", Json::num(m.resident_bytes as f64)));
                    if let Some(mix) = &m.tenant_mix {
                        fields.push(("tenant_mix", Json::str(mix.as_str())));
                        fields.push((
                            "tenant_counts",
                            Json::Arr(
                                m.tenant_counts.iter().map(|&n| Json::num(n as f64)).collect(),
                            ),
                        ));
                        fields.push((
                            "tenant_p95_ms",
                            Json::Arr(m.tenant_p95_ms.iter().map(|&p| Json::num(p)).collect()),
                        ));
                    }
                    if let (Some(s), Some(l)) = (m.swaps, m.lost) {
                        fields.push(("swaps", Json::num(s as f64)));
                        fields.push(("lost", Json::num(l as f64)));
                    }
                }
                Json::obj(fields)
            })
            .collect(),
    );
    let doc = Json::obj(vec![
        ("bench", Json::str("serve_shard_sweep")),
        (
            "detector",
            Json::str(
                "synthetic width-8, 3 stages, b=6 shift + f32 engines, planned+naive executors, threads {1,4} tile pools, fixed+adaptive batch windows (open-loop steady/bursty), elastic shards-auto cells (open-loop bursty, scale events recorded), simd on/off kernel-backend cells (forced-scalar baselines when SIMD is detected)",
            ),
        ),
        ("rows", rows),
    ]);
    std::fs::write("BENCH_serve.json", doc.to_string())?;
    println!("\nwrote BENCH_serve.json ({} cells)", cells.len());
    Ok(())
}
