//! Serving benchmark — thin driver over the experiment lab.
//!
//! The sweep itself (grid cells, window/load comparisons, autoscale,
//! trained-checkpoint, fault-storm, tenant and swap cells) lives in
//! `lbw_net::lab::runner`; this binary just picks a plan and runs it:
//!
//! * `--smoke` (CI): the committed `plans/ci-smoke.toml`, serve trials
//!   only — identical cells to `repro lab run ci-smoke --only serve`,
//!   so a bench run and a lab run share one content-addressed run
//!   directory and resume each other's completed trials instead of
//!   re-measuring (and `BENCH_serve.json` is regenerated in place, not
//!   appended to — re-running an identical cell can no longer clobber
//!   or duplicate the accumulated rows).
//! * default (full): a wider built-in plan — 192 requests, shards
//!   {1,2,4}, batch windows {0,2} ms — for local deep measurements.
//!
//! `BENCH_SERVE_REQUESTS` overrides the request budget; the override
//! is hashed into the run id like any other knob, so different budgets
//! never share trials.

use std::path::Path;

use anyhow::{Context, Result};

use lbw_net::lab::plan::{Plan, ServeGrid, KNOWN_EXTRAS};
use lbw_net::lab::runner::{self, RunOpts};
use lbw_net::lab::store::LabStore;

fn full_plan() -> Plan {
    Plan {
        name: "bench-serve-full".to_string(),
        repeats: 1,
        seed: 4242,
        requests: 192,
        concurrency: 8,
        serve: Some(ServeGrid {
            engines: vec!["float".into(), "shift6".into()],
            executors: vec!["planned".into(), "naive".into()],
            shards: vec![1, 2, 4],
            threads: vec![1, 4],
            window_ms: vec![0, 2],
            simd: vec!["auto".into(), "off".into()],
            extras: KNOWN_EXTRAS.iter().map(|s| s.to_string()).collect(),
            trained_steps: 120,
        }),
        train: None,
    }
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut plan = if smoke {
        Plan::load(Path::new("plans/ci-smoke.toml"))
            .context("bench_serve --smoke drives the committed CI plan")?
    } else {
        full_plan()
    };
    if let Some(req) = std::env::var("BENCH_SERVE_REQUESTS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        anyhow::ensure!(
            req >= 1 && req % plan.concurrency == 0,
            "BENCH_SERVE_REQUESTS ({req}) must be a positive multiple of concurrency ({})",
            plan.concurrency
        );
        plan.requests = req;
    }
    println!(
        "bench_serve{}: plan `{}` -> {}",
        if smoke { " (smoke)" } else { "" },
        plan.name,
        plan.run_id()
    );
    let store = LabStore::new(LabStore::default_root());
    let opts = RunOpts { force: false, only: Some("serve".to_string()), quiet: false };
    let report = runner::run_plan(&plan, &store, &opts)?;
    println!(
        "{} executed, {} resumed -> {}",
        report.executed,
        report.resumed,
        report.run_dir.display()
    );
    let (serve_rows, _train_rows) = runner::export_flat(
        &store,
        &report.run_id,
        Path::new("BENCH_serve.json"),
        Path::new("BENCH_train.json"),
    )?;
    println!("\n--- summary ({} serve rows -> BENCH_serve.json) ---", serve_rows.len());
    runner::print_serve_summary(&serve_rows);
    Ok(())
}
