//! Serving load generator: sweep shard count × batch window over
//! SynthVOC scenes and record the throughput/latency trajectory.
//!
//! Fully hermetic — the sweep drives the pure-Rust engines behind the
//! sharded server on a synthetic He-initialized detector, so it runs
//! on a clean checkout (no Python, no artifacts). Emits
//! `BENCH_serve.json`: one row per (engine, shards, batch window)
//! cell with wall time, img/s, latency percentiles, mean batch
//! occupancy, and the per-shard request counts.
//!
//! Run with: `cargo run --release --example bench_serve`

use std::time::{Duration, Instant};

use anyhow::Result;
use lbw_net::coordinator::server::{DetectServer, ServerConfig};
use lbw_net::data::{generate_scene, SceneConfig};
use lbw_net::nn::synth::{synthetic_checkpoint, synthetic_spec, SynthConfig};
use lbw_net::nn::EngineKind;
use lbw_net::util::json::Json;

const REQUESTS: usize = 192;
const CONCURRENCY: usize = 8;

struct Cell {
    engine: String,
    shards: usize,
    window_ms: u64,
    wall_s: f64,
    imgs_per_s: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    mean_batch: f64,
    shard_counts: Vec<usize>,
}

fn drive(server: &DetectServer, scenes: &[Vec<f32>]) -> Result<Duration> {
    let handle = server.handle();
    let t0 = Instant::now();
    let per = REQUESTS / CONCURRENCY;
    let mut clients = Vec::new();
    for c in 0..CONCURRENCY {
        let h = handle.clone();
        let imgs: Vec<Vec<f32>> =
            (0..per).map(|i| scenes[(c * per + i) % scenes.len()].clone()).collect();
        clients.push(std::thread::spawn(move || -> Result<()> {
            for img in imgs {
                h.detect(img)?;
            }
            Ok(())
        }));
    }
    for c in clients {
        c.join().expect("client thread")?;
    }
    Ok(t0.elapsed())
}

fn main() -> Result<()> {
    let spec = synthetic_spec(SynthConfig::default());
    let ckpt = synthetic_checkpoint(&spec, 2027, 6);
    let scene_cfg = SceneConfig::default();
    let scenes: Vec<Vec<f32>> =
        (0..32u64).map(|i| generate_scene(4242, i, &scene_cfg).image).collect();

    println!(
        "=== bench_serve: {REQUESTS} requests, {CONCURRENCY} clients, synthetic detector ==="
    );
    println!(
        "{:<8} {:<7} {:<10} {:>9} {:>9} {:>9} {:>9} {:>11}",
        "engine", "shards", "window", "img/s", "p50 ms", "p95 ms", "p99 ms", "mean batch"
    );

    let mut cells: Vec<Cell> = Vec::new();
    for (engine_name, engine) in
        [("float", EngineKind::Float), ("shift6", EngineKind::Shift { bits: 6 })]
    {
        for &shards in &[1usize, 2, 4] {
            for &window_ms in &[0u64, 2] {
                let cfg = ServerConfig {
                    shards,
                    max_batch: 8,
                    batch_window: Duration::from_millis(window_ms),
                    queue_depth: 256,
                    ..Default::default()
                };
                let server = DetectServer::start_engine(&spec, &ckpt, engine, cfg)?;
                let wall = drive(&server, &scenes)?;
                let agg = server.handle().latency();
                let shard_counts: Vec<usize> =
                    server.shard_latencies().iter().map(|s| s.count()).collect();
                let cell = Cell {
                    engine: engine_name.to_string(),
                    shards,
                    window_ms,
                    wall_s: wall.as_secs_f64(),
                    imgs_per_s: agg.throughput(wall),
                    p50_ms: agg.percentile_ms(50.0),
                    p95_ms: agg.percentile_ms(95.0),
                    p99_ms: agg.percentile_ms(99.0),
                    mean_batch: agg.mean_batch(),
                    shard_counts,
                };
                println!(
                    "{:<8} {:<7} {:<10} {:>9.1} {:>9.2} {:>9.2} {:>9.2} {:>11.2}",
                    cell.engine,
                    cell.shards,
                    format!("{window_ms}ms"),
                    cell.imgs_per_s,
                    cell.p50_ms,
                    cell.p95_ms,
                    cell.p99_ms,
                    cell.mean_batch
                );
                server.shutdown();
                cells.push(cell);
            }
        }
    }

    // scaling summary: shards=4 vs shards=1 at the same window/engine
    for engine in ["float", "shift6"] {
        let rate = |shards: usize| {
            cells
                .iter()
                .find(|c| c.engine == engine && c.shards == shards && c.window_ms == 2)
                .map(|c| c.imgs_per_s)
                .unwrap_or(0.0)
        };
        let (r1, r4) = (rate(1), rate(4));
        if r1 > 0.0 {
            println!("{engine}: 4-shard speedup over 1 shard = {:.2}x", r4 / r1);
        }
    }

    let rows = Json::Arr(
        cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("engine", Json::str(c.engine.as_str())),
                    ("shards", Json::num(c.shards as f64)),
                    ("batch_window_ms", Json::num(c.window_ms as f64)),
                    ("requests", Json::num(REQUESTS as f64)),
                    ("concurrency", Json::num(CONCURRENCY as f64)),
                    ("wall_s", Json::num(c.wall_s)),
                    ("imgs_per_s", Json::num(c.imgs_per_s)),
                    ("p50_ms", Json::num(c.p50_ms)),
                    ("p95_ms", Json::num(c.p95_ms)),
                    ("p99_ms", Json::num(c.p99_ms)),
                    ("mean_batch", Json::num(c.mean_batch)),
                    (
                        "shard_counts",
                        Json::Arr(c.shard_counts.iter().map(|&n| Json::num(n as f64)).collect()),
                    ),
                ])
            })
            .collect(),
    );
    let doc = Json::obj(vec![
        ("bench", Json::str("serve_shard_sweep")),
        ("detector", Json::str("synthetic width-8, 3 stages, b=6 shift + f32 engines")),
        ("rows", rows),
    ]);
    std::fs::write("BENCH_serve.json", doc.to_string())?;
    println!("\nwrote BENCH_serve.json ({} cells)", cells.len());
    Ok(())
}
