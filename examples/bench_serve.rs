//! Serving load generator: sweep executor × engine × shard count ×
//! intra-op threads × batch window over SynthVOC scenes and record the
//! throughput/latency trajectory.
//!
//! Fully hermetic — the sweep drives the pure-Rust engines behind the
//! sharded server on a synthetic He-initialized detector, so it runs
//! on a clean checkout (no Python, no artifacts). Emits
//! `BENCH_serve.json`: one row per (executor, engine, shards, threads,
//! batch window) cell with wall time, img/s, latency percentiles, mean
//! batch occupancy, and the per-shard request counts. The `executor`
//! field distinguishes the planned arena executor (production path)
//! from the naive per-op reference; the `threads` field is the
//! per-shard tile-pool width (planned executor only — the naive walk
//! is always single-threaded). The summary prints the planned/naive
//! img/s ratio and the planned 4-thread/1-thread speedup per engine at
//! a single shard.
//!
//! Run with: `cargo run --release --example bench_serve`
//! Smoke mode (CI): `cargo run --release --example bench_serve -- --smoke`
//! (reduced request count + 1-shard cells only; also honours the
//! `BENCH_SERVE_REQUESTS` env var).

use std::time::{Duration, Instant};

use anyhow::Result;
use lbw_net::coordinator::server::{DetectServer, Executor, ServerConfig};
use lbw_net::data::{generate_scene, SceneConfig};
use lbw_net::nn::synth::{synthetic_checkpoint, synthetic_spec, SynthConfig};
use lbw_net::nn::EngineKind;
use lbw_net::util::json::Json;

const CONCURRENCY: usize = 8;

struct Cell {
    executor: String,
    engine: String,
    shards: usize,
    threads: usize,
    window_ms: u64,
    wall_s: f64,
    imgs_per_s: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    mean_batch: f64,
    shard_counts: Vec<usize>,
}

fn drive(server: &DetectServer, scenes: &[Vec<f32>], requests: usize) -> Result<Duration> {
    let handle = server.handle();
    let t0 = Instant::now();
    let per = requests / CONCURRENCY;
    let mut clients = Vec::new();
    for c in 0..CONCURRENCY {
        let h = handle.clone();
        let imgs: Vec<Vec<f32>> =
            (0..per).map(|i| scenes[(c * per + i) % scenes.len()].clone()).collect();
        clients.push(std::thread::spawn(move || -> Result<()> {
            for img in imgs {
                h.detect(img)?;
            }
            Ok(())
        }));
    }
    for c in clients {
        c.join().expect("client thread")?;
    }
    Ok(t0.elapsed())
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let requests: usize = std::env::var("BENCH_SERVE_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 48 } else { 192 });
    let shard_list: &[usize] = if smoke { &[1] } else { &[1, 2, 4] };
    let window_list: &[u64] = if smoke { &[2] } else { &[0, 2] };

    let spec = synthetic_spec(SynthConfig::default());
    let ckpt = synthetic_checkpoint(&spec, 2027, 6);
    let scene_cfg = SceneConfig::default();
    let scenes: Vec<Vec<f32>> =
        (0..32u64).map(|i| generate_scene(4242, i, &scene_cfg).image).collect();

    println!(
        "=== bench_serve: {requests} requests, {CONCURRENCY} clients, synthetic detector{} ===",
        if smoke { " (smoke)" } else { "" }
    );
    println!(
        "{:<9} {:<8} {:<7} {:<8} {:<10} {:>9} {:>9} {:>9} {:>9} {:>11}",
        "executor", "engine", "shards", "threads", "window", "img/s", "p50 ms", "p95 ms",
        "p99 ms", "mean batch"
    );

    let mut cells: Vec<Cell> = Vec::new();
    for (exec_name, executor) in [("planned", Executor::Planned), ("naive", Executor::Naive)] {
        // the tile pool only feeds the planned executor's kernels; the
        // naive walk is single-threaded by construction
        let thread_list: &[usize] = match executor {
            Executor::Planned => &[1, 4],
            Executor::Naive => &[1],
        };
        for (engine_name, engine) in
            [("float", EngineKind::Float), ("shift6", EngineKind::Shift { bits: 6 })]
        {
            for &shards in shard_list {
                for &threads in thread_list {
                    for &window_ms in window_list {
                        let cfg = ServerConfig {
                            shards,
                            threads,
                            max_batch: 8,
                            batch_window: Duration::from_millis(window_ms),
                            queue_depth: 256,
                            executor,
                            ..Default::default()
                        };
                        let server = DetectServer::start_engine(&spec, &ckpt, engine, cfg)?;
                        let wall = drive(&server, &scenes, requests)?;
                        let agg = server.handle().latency();
                        let snap = agg.snapshot();
                        let shard_counts: Vec<usize> =
                            server.shard_latencies().iter().map(|s| s.count()).collect();
                        let cell = Cell {
                            executor: exec_name.to_string(),
                            engine: engine_name.to_string(),
                            shards,
                            threads,
                            window_ms,
                            wall_s: wall.as_secs_f64(),
                            imgs_per_s: agg.throughput(wall),
                            p50_ms: snap.percentile_ms(50.0),
                            p95_ms: snap.percentile_ms(95.0),
                            p99_ms: snap.percentile_ms(99.0),
                            mean_batch: agg.mean_batch(),
                            shard_counts,
                        };
                        println!(
                            "{:<9} {:<8} {:<7} {:<8} {:<10} {:>9.1} {:>9.2} {:>9.2} {:>9.2} {:>11.2}",
                            cell.executor,
                            cell.engine,
                            cell.shards,
                            cell.threads,
                            format!("{window_ms}ms"),
                            cell.imgs_per_s,
                            cell.p50_ms,
                            cell.p95_ms,
                            cell.p99_ms,
                            cell.mean_batch
                        );
                        server.shutdown();
                        cells.push(cell);
                    }
                }
            }
        }
    }

    let rate = |exec: &str, engine: &str, shards: usize, threads: usize| {
        cells
            .iter()
            .find(|c| {
                c.executor == exec
                    && c.engine == engine
                    && c.shards == shards
                    && c.threads == threads
                    && c.window_ms == 2
            })
            .map(|c| c.imgs_per_s)
            .unwrap_or(0.0)
    };
    // the headline ratio: planned vs naive through the identical
    // serving stack, single shard, single thread (the ISSUE-2
    // acceptance number)
    for engine in ["float", "shift6"] {
        let (p, n) = (rate("planned", engine, 1, 1), rate("naive", engine, 1, 1));
        if n > 0.0 {
            println!("{engine}: planned/naive single-shard speedup = {:.2}x", p / n);
        }
    }
    // intra-op scaling: 4-thread vs 1-thread pools at a single shard
    // (the ISSUE-3 acceptance number)
    for engine in ["float", "shift6"] {
        let (t4, t1) = (rate("planned", engine, 1, 4), rate("planned", engine, 1, 1));
        if t1 > 0.0 {
            println!(
                "{engine}: planned 4-thread/1-thread speedup at 1 shard = {:.2}x",
                t4 / t1
            );
        }
    }
    if !smoke {
        // scaling summary on the production path: shards=4 vs shards=1
        for engine in ["float", "shift6"] {
            let (r1, r4) = (rate("planned", engine, 1, 1), rate("planned", engine, 4, 1));
            if r1 > 0.0 {
                println!("{engine}: planned 4-shard speedup over 1 shard = {:.2}x", r4 / r1);
            }
        }
    }

    let rows = Json::Arr(
        cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("executor", Json::str(c.executor.as_str())),
                    ("engine", Json::str(c.engine.as_str())),
                    ("shards", Json::num(c.shards as f64)),
                    ("threads", Json::num(c.threads as f64)),
                    ("batch_window_ms", Json::num(c.window_ms as f64)),
                    ("requests", Json::num(requests as f64)),
                    ("concurrency", Json::num(CONCURRENCY as f64)),
                    ("wall_s", Json::num(c.wall_s)),
                    ("imgs_per_s", Json::num(c.imgs_per_s)),
                    ("p50_ms", Json::num(c.p50_ms)),
                    ("p95_ms", Json::num(c.p95_ms)),
                    ("p99_ms", Json::num(c.p99_ms)),
                    ("mean_batch", Json::num(c.mean_batch)),
                    (
                        "shard_counts",
                        Json::Arr(c.shard_counts.iter().map(|&n| Json::num(n as f64)).collect()),
                    ),
                ])
            })
            .collect(),
    );
    let doc = Json::obj(vec![
        ("bench", Json::str("serve_shard_sweep")),
        (
            "detector",
            Json::str(
                "synthetic width-8, 3 stages, b=6 shift + f32 engines, planned+naive executors, threads {1,4} tile pools",
            ),
        ),
        ("rows", rows),
    ]);
    std::fs::write("BENCH_serve.json", doc.to_string())?;
    println!("\nwrote BENCH_serve.json ({} cells)", cells.len());
    Ok(())
}
