//! Quantization analysis (§2.1 of the paper, no artifacts required):
//!
//! * exact Theorem-1 ternary solver vs the eq.(3) semi-analytical
//!   scheme vs baselines (TWN / XNOR / BinaryConnect / DoReFa / INQ),
//! * the combinatorial exact solution at b=3,4 on small vectors,
//! * the µ sweep: how the free parameter trades L2 error against
//!   sparsity and large-weight fidelity,
//! * Fig. 2-style non-Gaussianity of a heavy-tailed weight ensemble.
//!
//! Run with: `cargo run --release --example quant_analysis`

use lbw_net::data::Rng;
use lbw_net::quant::{baselines, exact, l2_err, stats, threshold};

fn heavy_tailed(n: usize, seed: u64) -> Vec<f32> {
    // product-of-normals: excess kurtosis >> 0, like trained conv layers
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() * 0.03 * (1.0 + rng.normal().abs())).collect()
}

fn main() {
    let w = heavy_tailed(8192, 7);

    // --- scheme comparison ------------------------------------------------
    println!("=== L2 approximation error, 8192 heavy-tailed weights ===");
    println!("{:<22} {:>14} {:>10} {:>6}", "scheme", "L2 err", "sparsity", "s");
    let t = exact::ternary_exact(&w);
    println!("{:<22} {:>14.6e} {:>10.3} {:>6}", "exact ternary (Thm 1)", t.err,
             1.0 - t.counts[0] as f64 / w.len() as f64, t.s);
    for bits in [2u32, 4, 5, 6] {
        let q = threshold::lbw_quantize_layer(&w, bits, 0.75);
        println!(
            "{:<22} {:>14.6e} {:>10.3} {:>6}",
            format!("LBW eq.(3) b={bits}"),
            l2_err(&w, &q.wq),
            q.sparsity(),
            q.s
        );
    }
    for (name, wq) in [
        ("BinaryConnect", baselines::binary_connect(&w)),
        ("XNOR scaled sign", baselines::xnor(&w)),
        ("TWN", baselines::twn(&w)),
        ("DoReFa b=4", baselines::dorefa(&w, 4)),
        ("INQ round b=5", baselines::inq_round(&w, 5)),
    ] {
        println!("{:<22} {:>14.6e}", name, l2_err(&w, &wq));
    }

    // --- exactness check on small vectors ---------------------------------
    println!("\n=== Theorem-1 enumeration vs eq.(3) scheme (N=14) ===");
    println!("{:<6} {:>14} {:>14} {:>8}", "bits", "exact err", "eq.(3) err", "ratio");
    for bits in [2u32, 3, 4] {
        let mut exact_sum = 0.0;
        let mut approx_sum = 0.0;
        for seed in 0..20 {
            let v = heavy_tailed(14, 100 + seed);
            exact_sum += exact::exact_enumerate(&v, bits).err;
            approx_sum += l2_err(&v, &threshold::lbw_quantize_layer(&v, bits, 0.75).wq);
        }
        println!(
            "{:<6} {:>14.6e} {:>14.6e} {:>8.3}",
            bits,
            exact_sum / 20.0,
            approx_sum / 20.0,
            approx_sum / exact_sum
        );
    }

    // --- mu sweep ----------------------------------------------------------
    println!("\n=== µ sweep at b=4 (µ = ratio · ‖W‖∞; paper picks 0.75) ===");
    println!("{:<8} {:>14} {:>10} {:>16}", "ratio", "L2 err", "sparsity", "top-level share");
    for k in 1..=10 {
        let ratio = k as f32 / 10.0;
        let q = threshold::lbw_quantize_layer(&w, 4, ratio);
        let counts = q.level_counts(4);
        let nz: usize = counts.iter().sum();
        println!(
            "{:<8.2} {:>14.6e} {:>10.3} {:>16.3}",
            ratio,
            l2_err(&w, &q.wq),
            q.sparsity(),
            if nz > 0 { counts[0] as f64 / nz as f64 } else { 0.0 }
        );
    }
    println!("(low µ minimizes L2; µ=0.75 keeps the large weights at full scale — the\n paper selects it on detection mAP, not on approximation error)");

    // --- Fig. 2-style normality -------------------------------------------
    println!("\n=== Fig. 2 analogue: normality of the weight ensemble ===");
    let m = stats::moments(&w);
    let jb = stats::jarque_bera(&w);
    println!(
        "n={} mean={:.5} std={:.5} skew={:.3} excess_kurtosis={:.3}",
        m.n, m.mean, m.std, m.skewness, m.excess_kurtosis
    );
    println!("Jarque-Bera={:.1} p={:.3e} (non-Gaussian, as the paper observes)", jb.statistic, jb.p_value);
    println!("\n{}", stats::render_histogram(&w, 25, 44));
}
