//! Kernel micro-bench: scalar vs explicit-SIMD GEMM tiles (ISSUE 7).
//!
//! Times the two planned-executor hot loops in isolation — the fused
//! f32 conv+BN+ReLU GEMM (`gemm_bn_relu_on`) and the shift-add GEMM
//! over `DenseLanes` (`shift_gemm_bn_relu_on`) — at the width-8 and
//! width-13 layer shapes the determinism suite uses (width 13 covers
//! the ragged lane/tile tails). For each shape it runs the scalar
//! reference and the detected backend (AVX2/NEON, or scalar again on
//! hosts without either), verifies the outputs are **bitwise
//! identical**, and prints GFLOP-equivalents and the simd/scalar
//! speedup. "FLOP-equivalent" counts 2·m·k·cout ops per call for both
//! kernels so the shift engine's rate is directly comparable to the
//! float GEMM it replaces (the paper's shift-for-multiply story).
//!
//! Usage: `cargo run --release --example bench_kernels [-- --smoke]`
//! (`--smoke` shrinks rows/reps for CI).

use std::time::Instant;

use lbw_net::nn::conv::{gemm_bn_relu_on, pack_lanes, Residual, LANES};
use lbw_net::nn::shift_conv::{shift_gemm_bn_relu_on, ShiftConv, FIX};
use lbw_net::nn::{KernelBackend, SimdMode};
use lbw_net::quant::threshold::lbw_quantize_layer;

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut s = seed | 1;
    (0..n)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s >> 11) as f32 / (1u64 << 53) as f32 - 0.3
        })
        .collect()
}

fn assert_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} differs ({x} vs {y})");
    }
}

/// Best-of-`reps` wall time for `f`, in seconds.
fn time_best<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (m, reps) = if smoke { (256usize, 3usize) } else { (4096, 20) };
    let backend = KernelBackend::detect(SimdMode::from_env());
    println!(
        "=== bench_kernels: m = {m} patch rows, best of {reps}, backend = {} ===",
        backend.label()
    );
    println!(
        "{:<7} {:<9} {:>5} {:>6} {:>12} {:>12} {:>9}",
        "kernel", "shape", "k", "cout", "scalar GF/s", "simd GF/s", "speedup"
    );

    // the determinism-suite layer shapes: 3×3 convs at widths 8 and 13
    // (width 13 exercises the padded-lane and ragged-tile tails)
    for &width in &[8usize, 13] {
        let (kh, kw, cin, cout) = (3usize, 3usize, width, 2 * width);
        let k = kh * kw * cin;
        let flops = 2.0 * m as f64 * k as f64 * cout as f64;
        let a = randv(m * k, 0xA11CE ^ width as u64);
        let w = randv(k * cout, 0xB0B ^ width as u64);
        let scale = randv(cout, 3 ^ width as u64);
        let bias = randv(cout, 5 ^ width as u64);

        // --- f32 GEMM ---
        let (cp, b) = pack_lanes(&w, k, cout);
        let mut out_s = vec![0.0f32; m * cout];
        let mut out_v = vec![0.0f32; m * cout];
        let ts = time_best(reps, || {
            gemm_bn_relu_on(
                KernelBackend::Scalar,
                &a,
                m,
                k,
                &b,
                cout,
                cp,
                &scale,
                &bias,
                true,
                &Residual::None,
                &mut out_s,
            )
        });
        let tv = time_best(reps, || {
            gemm_bn_relu_on(
                backend, &a, m, k, &b, cout, cp, &scale, &bias, true, &Residual::None, &mut out_v,
            )
        });
        assert_bitwise(&out_s, &out_v, &format!("f32 gemm width {width}"));
        println!(
            "{:<7} {:<9} {:>5} {:>6} {:>12.2} {:>12.2} {:>8.2}x",
            "float",
            format!("w{width} 3x3"),
            k,
            cout,
            flops / ts / 1e9,
            flops / tv / 1e9,
            ts / tv
        );

        // --- shift-add GEMM (6-bit LBW weights, 16.16 activations) ---
        let q = lbw_quantize_layer(&w, 6, 0.75);
        let sc = ShiftConv::from_quant(&q, kh, kw, cin, cout, 6);
        let lanes = sc.dense_lanes(LANES);
        let scale_out = f32::powi(2.0, sc.s - FIX);
        let aq: Vec<i32> = a.iter().map(|&v| (v * (1 << FIX) as f32).round() as i32).collect();
        let ts = time_best(reps, || {
            shift_gemm_bn_relu_on(
                KernelBackend::Scalar,
                &aq,
                m,
                k,
                &lanes,
                scale_out,
                cout,
                &scale,
                &bias,
                true,
                &Residual::None,
                &mut out_s,
            )
        });
        let tv = time_best(reps, || {
            shift_gemm_bn_relu_on(
                backend,
                &aq,
                m,
                k,
                &lanes,
                scale_out,
                cout,
                &scale,
                &bias,
                true,
                &Residual::None,
                &mut out_v,
            )
        });
        assert_bitwise(&out_s, &out_v, &format!("shift gemm width {width}"));
        println!(
            "{:<7} {:<9} {:>5} {:>6} {:>12.2} {:>12.2} {:>8.2}x",
            "shift6",
            format!("w{width} 3x3"),
            k,
            cout,
            flops / ts / 1e9,
            flops / tv / 1e9,
            ts / tv
        );
    }

    if !backend.is_simd() {
        println!("(no SIMD backend on this host — both columns ran the scalar kernels)");
    }
}
