//! Serving demo: the batched detection server under concurrent load,
//! with the Fig.-1-style qualitative comparison between the float model
//! and the 6-bit LBW model on the same scenes.
//!
//! Run with: `cargo run --release --example serve_detect`
//! (expects a checkpoint from `examples/train_detect` or `repro train`;
//! falls back to a fresh short training run if none exists.)

use std::path::Path;

use anyhow::Result;
use lbw_net::coordinator::params::Checkpoint;
use lbw_net::coordinator::server::{DetectServer, ServerConfig};
use lbw_net::coordinator::trainer::{TrainConfig, Trainer};
use lbw_net::data::{generate_scene, SceneConfig, ShapeClass};
use lbw_net::runtime::Runtime;

fn get_checkpoint() -> Result<Checkpoint> {
    let path = Path::new("train_detect_b6.lbw");
    if path.exists() {
        println!("using checkpoint {}", path.display());
        return Checkpoint::load(path);
    }
    println!("no checkpoint found; training 120 quick steps first...");
    let rt = Runtime::open_default()?;
    let trainer = Trainer::new(
        &rt,
        TrainConfig { bits: 6, steps: 120, train_scenes: 512, eval_scenes: 32, log_every: 40, ..Default::default() },
    )?;
    Ok(trainer.train()?.checkpoint)
}

fn main() -> Result<()> {
    let ck = get_checkpoint()?;

    // --- batched serving under concurrent load --------------------------
    let server = DetectServer::start(
        &ck.arch,
        ck.bits,
        ck.params.clone(),
        ck.state.clone(),
        ServerConfig::default(),
    )?;
    let handle = server.handle();
    let requests = 96usize;
    let concurrency = 6usize;
    let t0 = std::time::Instant::now();
    let mut clients = Vec::new();
    for c in 0..concurrency {
        let h = handle.clone();
        clients.push(std::thread::spawn(move || {
            let cfg = SceneConfig::default();
            for i in 0..requests / concurrency {
                let s = generate_scene(999, (c * 100 + i) as u64, &cfg);
                h.detect(s.image).expect("detect");
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {requests} requests with {concurrency} concurrent clients in {wall:.2}s \
         -> {:.1} img/s",
        requests as f64 / wall
    );
    println!("latency: {}", handle.latency_summary());
    drop(handle);
    server.shutdown();

    // --- Fig. 1 analogue: float vs 6-bit on the same scenes -------------
    println!("\n=== Fig. 1 analogue: 32-bit vs 6-bit detections ===");
    let rt = Runtime::open_default()?;
    let infer32 = rt.load("infer_a_b32_bs1")?;
    let infer6 = rt.load("infer_a_b6_bs1")?;
    use lbw_net::detection::{decode_grid, nms};
    use lbw_net::runtime::{lit_f32, to_f32};
    for i in 0..3u64 {
        // scene 2 is "crowded": many objects, the paper's hard case
        let cfg = if i == 2 {
            SceneConfig { min_objects: 4, max_objects: 4, ..Default::default() }
        } else {
            SceneConfig::default()
        };
        let s = generate_scene(2024, i, &cfg);
        println!("scene {i}: {} ground-truth objects", s.objects.len());
        for (name, exe) in [("32-bit", &infer32), (" 6-bit", &infer6)] {
            let out = exe.run(&[
                lit_f32(&ck.params, &[ck.params.len()])?,
                lit_f32(&ck.state, &[ck.state.len()])?,
                lit_f32(&s.image, &[1, 64, 64, 3])?,
            ])?;
            let dets = nms(decode_grid(&to_f32(&out[0])?, &to_f32(&out[1])?, 0.35), 0.45);
            let matched = s
                .objects
                .iter()
                .filter(|g| dets.iter().any(|d| d.class == g.class && d.bbox.iou(&g.bbox) >= 0.5))
                .count();
            print!("  {name}: {} detections (matched {matched}/{})", dets.len(), s.objects.len());
            for d in &dets {
                print!(" [{} {:.2}]", ShapeClass::from_index(d.class).name(), d.score);
            }
            println!();
        }
    }
    Ok(())
}
