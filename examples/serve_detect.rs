//! Serving demo: the sharded batched detection server under concurrent
//! load, plus a Fig.-1-style qualitative comparison between the float
//! engine and the 6-bit LBW shift-add engine on the same scenes.
//!
//! Run with: `cargo run --release --example serve_detect`
//!
//! Hermetic by default: on a clean checkout (no Python artifacts) it
//! serves a synthetic He-initialized detector through the pure-Rust
//! engines. When AOT artifacts and a trained checkpoint
//! (`train_detect_b6.lbw`) exist, it uses those instead — same server,
//! same code path, better detections.

use std::path::Path;

use anyhow::Result;
use lbw_net::coordinator::params::{Checkpoint, ParamSpec};
use lbw_net::coordinator::server::{DetectServer, ServerConfig};
use lbw_net::data::{generate_scene, SceneConfig, ShapeClass};
use lbw_net::nn::synth::load_or_synthetic;
use lbw_net::nn::{DetectorModel, EngineKind};
use lbw_net::runtime::default_artifacts_dir;

/// Trained checkpoint + its artifact spec when present, else the
/// synthetic hermetic pair (one shared policy: `synth::load_or_synthetic`).
fn get_model() -> Result<(ParamSpec, Checkpoint)> {
    let ckpt_path = Path::new("train_detect_b6.lbw");
    let trained =
        ckpt_path.exists() && default_artifacts_dir().join("param_spec_a.json").exists();
    if trained {
        println!("using trained checkpoint {}", ckpt_path.display());
    } else {
        println!("no trained checkpoint/artifacts: using a synthetic He-initialized detector");
        println!(
            "(train one with `cargo run --release --example train_detect` after `make artifacts`)"
        );
    }
    load_or_synthetic(trained.then_some(ckpt_path), 6, 99)
}

fn main() -> Result<()> {
    let (spec, ck) = get_model()?;

    // --- sharded serving under concurrent load --------------------------
    // each shard compiles one reusable plan + activation arena at
    // startup (ServerConfig::executor defaults to Executor::Planned) —
    // batched requests then execute with zero per-request setup
    let shards = 2;
    let server = DetectServer::start_engine(
        &spec,
        &ck,
        EngineKind::Shift { bits: ck.bits.clamp(2, 6) },
        ServerConfig { shards, ..Default::default() },
    )?;
    let handle = server.handle();
    let requests = 96usize;
    let concurrency = 6usize;
    let t0 = std::time::Instant::now();
    let mut clients = Vec::new();
    for c in 0..concurrency {
        let h = handle.clone();
        clients.push(std::thread::spawn(move || {
            let cfg = SceneConfig::default();
            for i in 0..requests / concurrency {
                let s = generate_scene(999, (c * 100 + i) as u64, &cfg);
                h.detect(s.image).expect("detect");
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {requests} requests with {concurrency} concurrent clients on {shards} shards \
         in {wall:.2}s -> {:.1} img/s",
        requests as f64 / wall
    );
    println!("latency: {}", handle.latency_summary());
    drop(handle);
    server.shutdown();

    // --- Fig. 1 analogue: float engine vs 6-bit shift engine ------------
    // both engines run through the planned API: build once, compile a
    // single-image plan, reuse its arena across scenes
    println!("\n=== Fig. 1 analogue: f32 engine vs 6-bit shift-add engine ===");
    let float_engine = DetectorModel::build(&spec, &ck, EngineKind::Float)?;
    let shift_engine =
        DetectorModel::build(&spec, &ck, EngineKind::Shift { bits: ck.bits.clamp(2, 6) })?;
    let mut float_plan = float_engine.plan(1);
    let mut shift_plan = shift_engine.plan(1);
    use lbw_net::detection::{decode_grid, nms};
    for i in 0..3u64 {
        // scene 2 is "crowded": many objects, the paper's hard case
        let cfg = if i == 2 {
            SceneConfig { min_objects: 4, max_objects: 4, ..Default::default() }
        } else {
            SceneConfig::default()
        };
        let s = generate_scene(2024, i, &cfg);
        println!("scene {i}: {} ground-truth objects", s.objects.len());
        for (name, plan) in [("  f32", &mut float_plan), ("shift", &mut shift_plan)] {
            let (cp, rg) = plan.forward(&s.image, 1);
            let dets = nms(decode_grid(cp, rg, 0.35), 0.45);
            let matched = s
                .objects
                .iter()
                .filter(|g| dets.iter().any(|d| d.class == g.class && d.bbox.iou(&g.bbox) >= 0.5))
                .count();
            print!("  {name}: {} detections (matched {matched}/{})", dets.len(), s.objects.len());
            for d in &dets {
                print!(" [{} {:.2}]", ShapeClass::from_index(d.class).name(), d.score);
            }
            println!();
        }
    }
    println!(
        "\nshift engine: sparsity {:.1}%, weight storage {:.1} KiB (f32: {:.1} KiB, {:.1}x smaller)",
        shift_engine.mean_sparsity * 100.0,
        shift_engine.weight_bits as f64 / 8192.0,
        float_engine.weight_bits as f64 / 8192.0,
        float_engine.weight_bits as f64 / shift_engine.weight_bits as f64
    );
    Ok(())
}
