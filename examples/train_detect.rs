//! End-to-end validation driver (DESIGN.md "End-to-end validation").
//!
//! Trains the 6-bit LBW detector on SynthVOC for several hundred steps
//! through the AOT `train_step` artifact, logging the loss curve;
//! evaluates VOC mAP against the 32-bit float run from the SAME
//! initialization (the Table 1 protocol); saves a checkpoint; then
//! cross-checks the rust-native deployment engines (f32 and shift-add)
//! against the artifact numerics on the trained weights.
//!
//! Results recorded in EXPERIMENTS.md. Both runs are also emitted as
//! BENCH_train.json-schema rows (`BENCH_train_artifact.json`, profile
//! `"artifact"`) so the artifact and hermetic trajectories can be
//! compared row-for-row — the accuracy gate itself runs on the
//! hermetic `make bench-train-smoke` output, which covers every
//! method.
//!
//! Run with: `cargo run --release --example train_detect [STEPS]`

use std::time::Instant;

use anyhow::Result;
use lbw_net::coordinator::params::ParamSpec;
use lbw_net::coordinator::trainer::{
    save_outcome, write_bench_train, TrainConfig, TrainOutcome, Trainer, TrainRow,
};
use lbw_net::data::{generate_scene, SceneConfig};
use lbw_net::nn::{DetectorModel, EngineKind};
use lbw_net::quant::threshold::{compression_ratio, lbw_quantize_layer};
use lbw_net::runtime::{default_artifacts_dir, lit_f32, to_f32, Runtime};

/// An artifact-trainer outcome as a BENCH_train.json row. Quantization
/// distance and sparsity are recomputed from the final shadow weights
/// with the same `µ = ¾‖W‖∞` rule the training artifact projects with.
fn artifact_row(
    spec: &ParamSpec,
    out: &TrainOutcome,
    bits: u32,
    seed: u64,
    steps: u64,
    wall_s: f64,
) -> TrainRow {
    let mut dist2 = 0.0f64;
    let (mut zeros, mut total) = (0usize, 0usize);
    if bits < 32 {
        for e in spec.conv_entries() {
            let w = &out.checkpoint.params[e.offset..e.offset + e.size];
            let q = lbw_quantize_layer(w, bits, 0.75);
            for (a, b) in w.iter().zip(&q.wq) {
                let d = (a - b) as f64;
                dist2 += d * d;
                if *b == 0.0 {
                    zeros += 1;
                }
            }
            total += e.size;
        }
    }
    TrainRow {
        method: if bits >= 32 { "float".into() } else { format!("lbw-{bits}") },
        bits,
        seed,
        steps,
        profile: "artifact".into(),
        map: out.final_map,
        quant_dist: dist2.sqrt(),
        sparsity: zeros as f64 / total.max(1) as f64,
        compression: if bits >= 32 { 1.0 } else { compression_ratio(bits) },
        loss_first: out.history.first().map_or(f64::NAN, |h| h.loss as f64),
        loss_last: out.history.last().map_or(f64::NAN, |h| h.loss as f64),
        wall_s,
    }
}

fn main() -> Result<()> {
    let steps: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(400);
    let rt = Runtime::open_default()?;
    println!("platform: {} | training {} steps", rt.platform(), steps);

    let base = TrainConfig {
        arch: "a".into(),
        steps,
        train_scenes: 2000,
        eval_scenes: 200,
        log_every: 20,
        ..Default::default()
    };

    // --- 6-bit LBW run --------------------------------------------------
    println!("\n=== 6-bit LBW-Net ===");
    let t6 = Trainer::new(&rt, TrainConfig { bits: 6, ..base.clone() })?;
    let t0 = Instant::now();
    let out6 = t6.train()?;
    let wall6 = t0.elapsed().as_secs_f64();
    println!("loss curve (step, loss):");
    for h in &out6.history {
        println!("  {:>5} {:.4}", h.step, h.loss);
    }
    println!("6-bit mAP: {:.4} ({:.0} ms/step)", out6.final_map, out6.mean_step_ms);

    // --- float baseline, same seed/init ---------------------------------
    println!("\n=== 32-bit float baseline (same init) ===");
    let t32 = Trainer::new(&rt, TrainConfig { bits: 32, log_every: steps / 4, ..base.clone() })?;
    let t0 = Instant::now();
    let out32 = t32.train()?;
    let wall32 = t0.elapsed().as_secs_f64();
    println!("32-bit mAP: {:.4}", out32.final_map);
    println!(
        "\nTable-1-style gap: 6-bit is {:.2} mAP points below float \
         (paper: < 1 point at convergence)",
        (out32.final_map - out6.final_map) * 100.0
    );

    // --- checkpoint ------------------------------------------------------
    let ckpt_path = std::path::PathBuf::from("train_detect_b6.lbw");
    save_outcome(&out6, &ckpt_path)?;
    println!("checkpoint -> {} (+ .history.jsonl)", ckpt_path.display());

    // --- deployment cross-check -----------------------------------------
    println!("\n=== deployment engine cross-check ===");
    let spec = ParamSpec::load_from_dir(&default_artifacts_dir(), "a")?;

    // --- accuracy-trajectory rows (BENCH_train.json schema) --------------
    let rows = vec![
        artifact_row(&spec, &out32, 32, base.seed, steps, wall32),
        artifact_row(&spec, &out6, 6, base.seed, steps, wall6),
    ];
    let bench_path = std::path::Path::new("BENCH_train_artifact.json");
    write_bench_train(bench_path, "artifact", &rows)?;
    println!("trajectory rows -> {}", bench_path.display());

    let ck = &out6.checkpoint;
    let mut float_engine = DetectorModel::build(&spec, ck, EngineKind::Float)?;
    let mut shift_engine = DetectorModel::build(&spec, ck, EngineKind::Shift { bits: 6 })?;
    let infer = rt.load("infer_a_b6_bs1")?;
    let mut max_d_art_shift = 0.0f32;
    for i in 0..4u64 {
        let s = generate_scene(31337, i, &SceneConfig::default());
        let art = infer.run(&[
            lit_f32(&ck.params, &[ck.params.len()])?,
            lit_f32(&ck.state, &[ck.state.len()])?,
            lit_f32(&s.image, &[1, 64, 64, 3])?,
        ])?;
        let cls_art = to_f32(&art[0])?;
        let (cls_shift, _) = shift_engine.forward(&s.image, 1);
        let (_cls_float, _) = float_engine.forward(&s.image, 1);
        let d: f32 = cls_art
            .iter()
            .zip(&cls_shift)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        max_d_art_shift = max_d_art_shift.max(d);
    }
    println!(
        "max |cls_prob| gap, artifact(b6) vs rust shift-add engine: {max_d_art_shift:.4}"
    );
    println!(
        "shift engine: mean conv sparsity {:.1}%, weight storage {:.1} KiB (vs {:.1} KiB float, {:.1}x smaller)",
        shift_engine.mean_sparsity * 100.0,
        shift_engine.weight_bits as f64 / 8.0 / 1024.0,
        float_engine.weight_bits as f64 / 8.0 / 1024.0,
        float_engine.weight_bits as f64 / shift_engine.weight_bits as f64
    );
    Ok(())
}
