# LBW-Net build entry points.
#
#   make build      release build (lib + repro binary)
#   make test       tier-1 verify: full hermetic test suite
#   make artifacts  AOT-lower the JAX/Pallas graphs to HLO text
#                   (needs the python env; optional — everything in
#                   `make test` passes without artifacts)
#   make bench      run every in-tree benchmark binary
#   make bench-smoke  reduced bench_serve sweep (planned vs naive
#                   executors, 1 shard, tile pools at 1 and 4 threads,
#                   plus the adaptive-vs-fixed window cells under
#                   open-loop steady/bursty load) — fast enough for
#                   CI; kernel, threading, or batching-controller
#                   regressions fail loudly here
#   make lint       rustfmt + clippy, as CI runs them

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test artifacts bench bench-smoke lint clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

bench: build
	$(CARGO) bench

bench-smoke: build
	$(CARGO) run --release --example bench_serve -- --smoke

lint:
	$(CARGO) fmt --check
	$(CARGO) clippy --all-targets -- -D warnings

clean:
	$(CARGO) clean
