# LBW-Net build entry points.
#
#   make build      release build (lib + repro binary)
#   make test       tier-1 verify: full hermetic test suite
#   make artifacts  AOT-lower the JAX/Pallas graphs to HLO text
#                   (needs the python env; optional — everything in
#                   `make test` passes without artifacts)
#   make bench      run every in-tree benchmark binary
#   make bench-smoke  reduced bench_serve sweep (planned vs naive
#                   executors, 1 shard, tile pools at 1 and 4 threads,
#                   the adaptive-vs-fixed window cells under open-loop
#                   steady/bursty load, the elastic fixed-vs-autoscale
#                   cells under bursty load, the fault sweep: the
#                   closed-loop cell under a seeded crash-storm plan
#                   with retrying clients, plus the registry cells: a
#                   mixed-tenant two-model cell under 3:1 weighted-fair
#                   shares and a hot-swap-under-load cell) — fast
#                   enough for CI; kernel, threading, batching,
#                   autoscaling, crash-recovery, tenant-fairness, or
#                   swap regressions fail loudly here
#   make bench-gate   regression-gate the fresh BENCH_serve.json
#                   (self-tests the gate on doctored rows first, then
#                   fails if planned/naive < 2x, 4t/1t < 1.5x, the
#                   shift-engine simd/scalar ratio < 1.3x when SIMD
#                   rows are present, an autoscale row shows no scale
#                   events, a fault row lost a response / never
#                   respawned / never fired its storm plan, a hot-swap
#                   row lost a response, or a tenant row starved a
#                   listed class)
#   make bench-kernels  scalar-vs-SIMD GEMM micro-bench (f32 + shift
#                   kernels at the width-8/13 shapes, bitwise parity
#                   checked, GFLOP-equiv + speedup printed)
#   make bench-train-smoke  hermetic accuracy trajectory: train the
#                   float detector, quantize + retrain every method
#                   (exact ternary, LBW 4/6-bit, DoReFa, INQ) on 2
#                   seeds, write BENCH_train.json
#   make accuracy-gate  regression-gate the fresh BENCH_train.json
#                   (self-tests on doctored rows first, then fails if
#                   6-bit drifts > 0.06 mAP below float, ternary
#                   collapses, or the bit ordering inverts)
#   make lint       rustfmt + clippy, as CI runs them

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test artifacts bench bench-smoke bench-gate \
	bench-kernels bench-train-smoke accuracy-gate lint clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

bench: build
	$(CARGO) bench

bench-smoke: build
	$(CARGO) run --release --example bench_serve -- --smoke

bench-gate:
	$(PYTHON) scripts/bench_gate.py --self-test
	$(PYTHON) scripts/bench_gate.py BENCH_serve.json

bench-kernels: build
	$(CARGO) run --release --example bench_kernels

bench-train-smoke: build
	$(CARGO) run --release --example bench_train -- --smoke

accuracy-gate:
	$(PYTHON) scripts/accuracy_gate.py --self-test
	$(PYTHON) scripts/accuracy_gate.py BENCH_train.json

lint:
	$(CARGO) fmt --check
	$(CARGO) clippy --all-targets -- -D warnings

clean:
	$(CARGO) clean
