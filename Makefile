# LBW-Net build entry points.
#
#   make build      release build (lib + repro binary)
#   make test       tier-1 verify: full hermetic test suite
#   make artifacts  AOT-lower the JAX/Pallas graphs to HLO text
#                   (needs the python env; optional — everything in
#                   `make test` passes without artifacts)
#   make bench      run every in-tree benchmark binary
#   make bench-smoke  the serve half of the committed CI lab plan
#                   (`repro lab run plans/ci-smoke.toml --only serve`):
#                   the planned-vs-naive / thread / simd grid at 2
#                   repeats plus every named scenario cell (open-loop
#                   window cells, elastic autoscale, trained
#                   checkpoint, crash-storm, tenants, hot swap).
#                   Completed trials resume from lab/runs/<id>/ instead
#                   of re-measuring; BENCH_serve.json is regenerated in
#                   place from the run (no append clobbering)
#   make bench-gate   regression-gate the fresh BENCH_serve.json
#                   (self-tests the gate on doctored rows AND doctored
#                   lab tables first, then gates the lab tables:
#                   ratio floors — planned/naive 2x, 4t/1t 1.5x,
#                   simd/scalar 1.3x — compare cell means and fail
#                   only past the pooled std; the absolute laws
#                   (autoscale events, fault/swap rows lose nothing,
#                   tenants never starved) hold on every repeat)
#   make bench-kernels  scalar-vs-SIMD GEMM micro-bench (f32 + shift
#                   kernels at the width-8/13 shapes, bitwise parity
#                   checked, GFLOP-equiv + speedup printed)
#   make bench-train-smoke  the train half of the CI lab plan
#                   (`--only train`): float detector per seed, then
#                   every method (exact ternary, LBW 4/6-bit, DoReFa,
#                   INQ) on 2 seeds; resumes completed cells, writes
#                   BENCH_train.json from the lab tables
#   make accuracy-gate  regression-gate the fresh BENCH_train.json
#                   (self-tests on doctored rows + tables first, then
#                   fails if the 6-bit mean drifts > 0.06 mAP below
#                   float past the pooled seed std, ternary collapses,
#                   or the bit ordering inverts)
#   make lab-gc     remove lab runs no committed plan references
#   make lint       rustfmt + clippy, as CI runs them

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test artifacts bench bench-smoke bench-gate \
	bench-kernels bench-train-smoke accuracy-gate lab-gc lint clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

bench: build
	$(CARGO) bench

bench-smoke: build
	$(CARGO) run --release -- lab run plans/ci-smoke.toml --only serve

bench-gate:
	$(PYTHON) scripts/bench_gate.py --self-test
	$(PYTHON) scripts/bench_gate.py BENCH_serve.json

bench-kernels: build
	$(CARGO) run --release --example bench_kernels

bench-train-smoke: build
	$(CARGO) run --release -- lab run plans/ci-smoke.toml --only train

lab-gc: build
	$(CARGO) run --release -- lab gc

accuracy-gate:
	$(PYTHON) scripts/accuracy_gate.py --self-test
	$(PYTHON) scripts/accuracy_gate.py BENCH_train.json

lint:
	$(CARGO) fmt --check
	$(CARGO) clippy --all-targets -- -D warnings

clean:
	$(CARGO) clean
