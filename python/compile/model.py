# Layer-2: the LBW-Net detection model in JAX.
#
# microResNet backbone + R-FCN-lite position-sensitive detection head
# (DESIGN.md "Substitutions"), with the paper's projected-SGD training
# step: every convolution kernel is pushed through the Pallas LBW
# projection (eq. 3 + eq. 4) with straight-through gradients before the
# forward pass, so "the minibatch gradient is evaluated at the
# quantized weights, and a scaled gradient is subtracted from the
# full-precision weights" (section 2.2). Batch norm + Nesterov momentum
# as in the paper.
#
# Everything here is build-time only: aot.py lowers train_step / infer
# to HLO text and the rust coordinator drives those artifacts.
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import lbw, matmul as mm, psvote

# ----------------------------------------------------------------------
# Problem constants (mirrored in rust/src/data and rust/src/nn).
IMG = 64          # input image side (RGB, NHWC)
GRID = 8          # detection grid side (IMG / 8 total stride)
K = 3             # k x k position-sensitive groups (R-FCN's k=3)
NUM_CLASSES = 4   # SynthVOC object classes: circle, square, triangle, cross
NUM_CLS = NUM_CLASSES + 1  # + background at index 0
ANCHOR = 16.0     # box size anchor in pixels (log-space regression base)
BN_MOMENTUM = 0.9
BN_EPS = 1e-5


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Backbone depth/width preset.

    ``a`` plays the role of ResNet-50 in Table 1, ``b`` the deeper
    ResNet-101 (same two-depth axis, scaled to this testbed).
    """

    name: str
    blocks: Tuple[int, int, int]   # residual blocks per stage
    widths: Tuple[int, int, int]   # channels per stage
    head_width: int

    @property
    def stem_width(self) -> int:
        return self.widths[0]


ARCHS: Dict[str, ArchConfig] = {
    "a": ArchConfig("a", blocks=(1, 1, 1), widths=(16, 32, 64), head_width=64),
    "b": ArchConfig("b", blocks=(2, 2, 2), widths=(16, 32, 64), head_width=64),
}


# ----------------------------------------------------------------------
# Parameter specification: a deterministic, named layout of every
# trainable tensor (params) and every BN running statistic (state),
# flattened into single f32 vectors. The same spec is emitted as JSON at
# AOT time and parsed by rust/src/coordinator/params.rs — rust never
# hardcodes offsets.

@dataclasses.dataclass(frozen=True)
class ParamEntry:
    name: str
    shape: Tuple[int, ...]
    kind: str        # conv | bias | bn_scale | bn_bias | bn_mean | bn_var
    quantize: bool   # True for every convolution kernel (paper: all conv layers)
    offset: int
    size: int


def _conv_shape(kh, kw, cin, cout):
    return (kh, kw, cin, cout)  # HWIO, matches lax.conv dimension numbers


def _build_layer_list(arch: ArchConfig) -> List[Tuple[str, Tuple[int, ...], str, bool]]:
    """Forward-order list of (name, shape, kind, quantize)."""
    layers: List[Tuple[str, Tuple[int, ...], str, bool]] = []

    def conv(name, kh, kw, cin, cout):
        layers.append((f"{name}.w", _conv_shape(kh, kw, cin, cout), "conv", True))

    def bn(name, c):
        layers.append((f"{name}.scale", (c,), "bn_scale", False))
        layers.append((f"{name}.bias", (c,), "bn_bias", False))

    conv("stem", 3, 3, 3, arch.stem_width)
    bn("stem.bn", arch.stem_width)
    cin = arch.stem_width
    for si, (nblocks, cout) in enumerate(zip(arch.blocks, arch.widths)):
        for bi in range(nblocks):
            p = f"s{si}.b{bi}"
            conv(f"{p}.conv1", 3, 3, cin, cout)
            bn(f"{p}.bn1", cout)
            conv(f"{p}.conv2", 3, 3, cout, cout)
            bn(f"{p}.bn2", cout)
            if cin != cout:
                conv(f"{p}.skip", 1, 1, cin, cout)
            cin = cout
    conv("head", 3, 3, cin, arch.head_width)
    bn("head.bn", arch.head_width)
    # 1x1 heads run through the Pallas tiled matmul; stored as [Cin, Cout].
    layers.append(("cls.w", (arch.head_width, K * K * NUM_CLS), "conv", True))
    layers.append(("cls.b", (K * K * NUM_CLS,), "bias", False))
    layers.append(("reg.w", (arch.head_width, 4), "conv", True))
    layers.append(("reg.b", (4,), "bias", False))
    return layers


def param_spec(arch: ArchConfig) -> List[ParamEntry]:
    entries = []
    off = 0
    for name, shape, kind, q in _build_layer_list(arch):
        size = int(np.prod(shape))
        entries.append(ParamEntry(name, shape, kind, q, off, size))
        off += size
    return entries


def state_spec(arch: ArchConfig) -> List[ParamEntry]:
    """BN running mean/var, in forward order."""
    entries = []
    off = 0
    for name, shape, kind, _ in _build_layer_list(arch):
        if kind == "bn_scale":
            c = shape[0]
            base = name[: -len(".scale")]
            for leaf in ("mean", "var"):
                entries.append(ParamEntry(f"{base}.{leaf}", (c,), f"bn_{leaf}", False, off, c))
                off += c
    return entries


def num_params(arch: ArchConfig) -> int:
    sp = param_spec(arch)
    return sp[-1].offset + sp[-1].size


def num_state(arch: ArchConfig) -> int:
    sp = state_spec(arch)
    return sp[-1].offset + sp[-1].size


def unflatten(flat, spec: List[ParamEntry]):
    return {
        e.name: jax.lax.dynamic_slice(flat, (e.offset,), (e.size,)).reshape(e.shape)
        for e in spec
    }


def flatten_dict(d, spec: List[ParamEntry]):
    return jnp.concatenate([d[e.name].reshape(-1) for e in spec])


def init_params(arch: ArchConfig, seed: int = 0) -> np.ndarray:
    """He-normal conv init, BN scale 1 / bias 0, zero biases.

    All bit-widths share the *same* initial weights for a fair
    comparison, mirroring the shared-initialization protocol of the
    paper's Table 1 (section 3.1).
    """
    rng = np.random.default_rng(seed)
    out = np.zeros(num_params(arch), dtype=np.float32)
    for e in param_spec(arch):
        if e.kind == "conv":
            fan_in = int(np.prod(e.shape[:-1]))
            w = rng.normal(0.0, np.sqrt(2.0 / fan_in), e.size).astype(np.float32)
            out[e.offset : e.offset + e.size] = w
        elif e.kind == "bn_scale":
            out[e.offset : e.offset + e.size] = 1.0
        # bn_bias / bias stay zero
    return out


def init_state(arch: ArchConfig) -> np.ndarray:
    out = np.zeros(num_state(arch), dtype=np.float32)
    for e in state_spec(arch):
        if e.kind == "bn_var":
            out[e.offset : e.offset + e.size] = 1.0
    return out


# ----------------------------------------------------------------------
# Forward pass.

def _conv2d(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _batch_norm(x, scale, bias, mean, var, train: bool):
    """In train mode normalizes with batch statistics (and reports them
    for the running-average update); in eval mode uses the provided
    running statistics."""
    if train:
        m = jnp.mean(x, axis=(0, 1, 2))
        v = jnp.var(x, axis=(0, 1, 2))
    else:
        m, v = mean, var
    y = (x - m) * jax.lax.rsqrt(v + BN_EPS) * scale + bias
    return y, m, v


def _maybe_quantize(w, bits: int, mu_ratio):
    """Project conv weights through the LBW Pallas kernel (STE); identity
    at full precision (bits >= 32)."""
    if bits >= 32:
        return w
    return lbw.lbw_quantize_ste(w, bits, mu_ratio)


def _inq_effective(w, frozen, bits: int, mu_ratio):
    """INQ-style effective weights (Zhou et al. [25], the paper's main
    comparator): the `frozen` partition is replaced by its quantized
    value and receives NO gradient; the rest stays full-precision and
    trainable. µ comes from the full layer so frozen/trainable share
    the level grid."""
    return lbw.inq_effective(w, frozen, bits, mu_ratio)


def ps_vote(maps):
    """Position-sensitive voting over the detection grid (jnp oracle).

    maps: [B, G, G, K*K, C]. Group g = (dy, dx) in {-1,0,1}^2 holds the
    evidence "this cell looks like part (dy,dx) of an object"; the score
    of cell (y, x) averages group (dy, dx) read at neighbour
    (y+dy, x+dx) — the dense-grid analogue of R-FCN's PS-RoI pooling
    (k = 3). Zero padding outside the grid.

    The production graph uses the Pallas kernel
    (`kernels/psvote.py::ps_vote`); this jnp version is its pytest
    oracle and documents the semantics.
    """
    b, g1, g2, kk, c = maps.shape
    assert kk == K * K
    padded = jnp.pad(maps, ((0, 0), (1, 1), (1, 1), (0, 0), (0, 0)))
    votes = []
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            gidx = (dy + 1) * K + (dx + 1)
            votes.append(padded[:, 1 + dy : 1 + dy + g1, 1 + dx : 1 + dx + g2, gidx, :])
    return jnp.mean(jnp.stack(votes, axis=0), axis=0)  # [B, G, G, C]


def forward(pd, sd, x, arch: ArchConfig, bits: int, mu_ratio, train: bool, md=None):
    """Run the detector.

    pd/sd: name->tensor dicts (params / BN state). x: [B,64,64,3].
    ``md``: optional frozen-mask dict (same keys as pd) switching the
    weight transform from LBW projected-SGD to INQ incremental
    quantization. Returns (cls_logits, reg, new_state_dict).
    """
    new_state = {}

    def bn(name, h):
        y, m, v = _batch_norm(
            h, pd[f"{name}.scale"], pd[f"{name}.bias"],
            sd[f"{name}.mean"], sd[f"{name}.var"], train,
        )
        if train:
            new_state[f"{name}.mean"] = BN_MOMENTUM * sd[f"{name}.mean"] + (1 - BN_MOMENTUM) * m
            new_state[f"{name}.var"] = BN_MOMENTUM * sd[f"{name}.var"] + (1 - BN_MOMENTUM) * v
        else:
            new_state[f"{name}.mean"] = sd[f"{name}.mean"]
            new_state[f"{name}.var"] = sd[f"{name}.var"]
        return y

    def qw(name):
        if md is not None:
            return _inq_effective(pd[name], md[name], bits, mu_ratio)
        return _maybe_quantize(pd[name], bits, mu_ratio)

    h = _conv2d(x, qw("stem.w"), stride=2)
    h = jax.nn.relu(bn("stem.bn", h))
    cin = arch.stem_width
    for si, (nblocks, cout) in enumerate(zip(arch.blocks, arch.widths)):
        for bi in range(nblocks):
            p = f"s{si}.b{bi}"
            stride = 2 if (bi == 0 and si > 0) else 1
            r = _conv2d(h, qw(f"{p}.conv1.w"), stride=stride)
            r = jax.nn.relu(bn(f"{p}.bn1", r))
            r = _conv2d(r, qw(f"{p}.conv2.w"), stride=1)
            r = bn(f"{p}.bn2", r)
            if cin != cout:
                skip = _conv2d(h, qw(f"{p}.skip.w"), stride=stride)
            elif stride != 1:
                skip = h[:, ::stride, ::stride, :]
            else:
                skip = h
            h = jax.nn.relu(r + skip)
            cin = cout
    h = _conv2d(h, qw("head.w"), stride=1)
    h = jax.nn.relu(bn("head.bn", h))
    # 1x1 heads via the MXU-tiled Pallas matmul.
    cls_maps = mm.conv1x1(h, qw("cls.w"), pd["cls.b"])
    b = x.shape[0]
    cls_maps = cls_maps.reshape(b, GRID, GRID, K * K, NUM_CLS)
    cls_logits = psvote.ps_vote(cls_maps)
    reg = mm.conv1x1(h, qw("reg.w"), pd["reg.b"])
    return cls_logits, reg, new_state


# ----------------------------------------------------------------------
# Loss + projected-SGD train step.

def _smooth_l1(x):
    ax = jnp.abs(x)
    return jnp.where(ax < 1.0, 0.5 * x * x, ax - 0.5)


def detection_loss(cls_logits, reg, cls_t, box_t, pos):
    """Grid detection loss.

    cls_t: int32 [B,G,G] (0 = background, 1..NUM_CLASSES = object class);
    box_t: f32 [B,G,G,4] encoded (ty, tx, th, tw); pos: f32 [B,G,G] mask.
    Positives are upweighted 4x in the CE (the grid is background-heavy,
    playing the role of R-FCN's OHEM).
    """
    logp = jax.nn.log_softmax(cls_logits, axis=-1)
    onehot = jax.nn.one_hot(cls_t, NUM_CLS, dtype=jnp.float32)
    ce = -jnp.sum(onehot * logp, axis=-1)
    w = 1.0 + 3.0 * pos
    cls_loss = jnp.sum(ce * w) / jnp.sum(w)
    npos = jnp.maximum(jnp.sum(pos), 1.0)
    box_loss = jnp.sum(_smooth_l1(reg - box_t) * pos[..., None]) / npos
    return cls_loss, box_loss


def make_train_step(arch: ArchConfig, bits: int):
    """Build the jittable projected-SGD + Nesterov momentum step.

    Flat signature (all f32 unless noted):
      (params[P], vel[P], state[S], images[B,64,64,3], cls_t[B,G,G] i32,
       box_t[B,G,G,4], pos[B,G,G], lr[], momentum[], mu_ratio[], wd[])
      -> (params'[P], vel'[P], state'[S], loss[], cls_loss[], box_loss[])
    """
    pspec, sspec = param_spec(arch), state_spec(arch)

    def loss_fn(params, state, images, cls_t, box_t, pos, mu_ratio, wd):
        pd = unflatten(params, pspec)
        sd = unflatten(state, sspec)
        cls_logits, reg, new_sd = forward(pd, sd, images, arch, bits, mu_ratio, train=True)
        cls_loss, box_loss = detection_loss(cls_logits, reg, cls_t, box_t, pos)
        # Weight decay acts on the *full-precision* weights (the shadow
        # variables of projected SGD).
        l2 = sum(jnp.sum(pd[e.name] ** 2) for e in pspec if e.kind == "conv")
        loss = cls_loss + box_loss + 0.5 * wd * l2
        new_state = flatten_dict(new_sd, sspec)
        return loss, (cls_loss, box_loss, new_state)

    def train_step(params, vel, state, images, cls_t, box_t, pos, lr, momentum, mu_ratio, wd):
        (loss, (cls_loss, box_loss, new_state)), g = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params, state, images, cls_t, box_t, pos, mu_ratio, wd)
        # Nesterov momentum on the full-precision shadow weights.
        new_vel = momentum * vel - lr * g
        new_params = params + momentum * new_vel - lr * g
        return new_params, new_vel, new_state, loss, cls_loss, box_loss

    return train_step


def make_train_step_inq(arch: ArchConfig, bits: int):
    """INQ baseline train step (incremental network quantization).

    Same flat signature as make_train_step plus a `frozen[P]` mask after
    `pos`: frozen weights are pinned to their quantized values (zero
    gradient), the rest trains at full precision. The rust coordinator
    drives the INQ schedule (re-partitioning between phases).

      (params[P], vel[P], state[S], images, cls_t, box_t, pos,
       frozen[P], lr[], momentum[], mu_ratio[], wd[])
      -> (params'[P], vel'[P], state'[S], loss[], cls_loss[], box_loss[])
    """
    pspec, sspec = param_spec(arch), state_spec(arch)

    def loss_fn(params, state, images, cls_t, box_t, pos, frozen, mu_ratio, wd):
        pd = unflatten(params, pspec)
        sd = unflatten(state, sspec)
        md = unflatten(frozen, pspec)
        cls_logits, reg, new_sd = forward(
            pd, sd, images, arch, bits, mu_ratio, train=True, md=md
        )
        cls_loss, box_loss = detection_loss(cls_logits, reg, cls_t, box_t, pos)
        l2 = sum(jnp.sum(pd[e.name] ** 2) for e in pspec if e.kind == "conv")
        loss = cls_loss + box_loss + 0.5 * wd * l2
        return loss, (cls_loss, box_loss, flatten_dict(new_sd, sspec))

    def train_step(params, vel, state, images, cls_t, box_t, pos, frozen,
                   lr, momentum, mu_ratio, wd):
        (loss, (cls_loss, box_loss, new_state)), g = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params, state, images, cls_t, box_t, pos, frozen, mu_ratio, wd)
        # frozen weights receive no update (their grad is already 0 via
        # stop_gradient, but momentum could still drift them: mask it)
        live = 1.0 - frozen
        new_vel = (momentum * vel - lr * g) * live
        new_params = params + (momentum * new_vel - lr * g) * live
        return new_params, new_vel, new_state, loss, cls_loss, box_loss

    return train_step


def make_infer(arch: ArchConfig, bits: int):
    """Inference graph: quantized weights (b < 32), BN running stats,
    softmax class probabilities.

    (params[P], state[S], images[B,64,64,3])
      -> (cls_prob[B,G,G,NUM_CLS], reg[B,G,G,4])
    """
    pspec, sspec = param_spec(arch), state_spec(arch)

    def infer(params, state, images):
        pd = unflatten(params, pspec)
        sd = unflatten(state, sspec)
        mu_ratio = jnp.float32(0.75)  # paper's choice for b >= 4
        cls_logits, reg, _ = forward(pd, sd, images, arch, bits, mu_ratio, train=False)
        return jax.nn.softmax(cls_logits, axis=-1), reg

    return infer


def make_quantize_op(bits: int):
    """Standalone quantization graph: the parity oracle the rust
    implementation is integration-tested against.

    (w[N], mu[]) -> (wq[N], levels[N] i32, s[])
    """

    def quantize(w, mu):
        wq, t, s = lbw.lbw_quantize(w, mu, bits)
        return wq, t, s

    return quantize
