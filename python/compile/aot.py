# AOT exporter: lower the L2 graphs to HLO *text* artifacts for the
# rust runtime.
#
# HLO text (NOT lowered.compiler_ir("hlo") protos / .serialize()) is the
# interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
# instruction ids which xla_extension 0.5.1 (the version behind the
# published `xla` 0.1.6 crate) rejects; the text parser reassigns ids
# and round-trips cleanly. See /opt/xla-example/README.md.
#
# Usage:  cd python && python -m compile.aot --out-dir ../artifacts
#
# Emits, per DESIGN.md "Parameter/artifact contract":
#   train_step_{arch}_{bits}.hlo.txt
#   infer_{arch}_{bits}_bs{1,8}.hlo.txt
#   quantize_b{bits}.hlo.txt            (parity oracle, N = 4096)
#   param_spec_{arch}.json              (flat layout for rust)
#   manifest.json                       (artifact -> signature map)
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

TRAIN_BATCH = 8
QUANT_N = 4096
TRAIN_BITS = {"a": (2, 4, 5, 6, 32), "b": (4, 5, 6, 32)}
INQ_BITS = {"a": (4, 5), "b": ()}  # INQ baseline comparison runs on arch a
INFER_BATCHES = (1, 8)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _spec_json(entries):
    return [
        {
            "name": e.name,
            "shape": list(e.shape),
            "kind": e.kind,
            "quantize": e.quantize,
            "offset": e.offset,
            "size": e.size,
        }
        for e in entries
    ]


def export_one(out_dir, name, fn, args, manifest):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    t0 = time.time()
    # keep_unused: the fp32 train_step ignores mu_ratio; the artifact
    # signature must stay uniform across bit-widths for the rust driver.
    text = to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args))
    with open(path, "w") as f:
        f.write(text)
    manifest[name] = {
        "file": f"{name}.hlo.txt",
        "inputs": [[list(a.shape), str(a.dtype)] for a in args],
    }
    print(f"  {name}: {len(text)} chars in {time.time() - t0:.1f}s")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated artifact-name prefixes to export (for iteration)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {
        "img": M.IMG,
        "grid": M.GRID,
        "num_classes": M.NUM_CLASSES,
        "anchor": M.ANCHOR,
        "train_batch": TRAIN_BATCH,
        "quant_n": QUANT_N,
        "artifacts": {},
    }
    only = args.only.split(",") if args.only else None

    def want(name):
        return only is None or any(name.startswith(p) for p in only)

    for arch_name, arch in M.ARCHS.items():
        P, S = M.num_params(arch), M.num_state(arch)
        spec = {
            "arch": arch_name,
            "num_params": P,
            "num_state": S,
            "params": _spec_json(M.param_spec(arch)),
            "state": _spec_json(M.state_spec(arch)),
        }
        with open(os.path.join(args.out_dir, f"param_spec_{arch_name}.json"), "w") as f:
            json.dump(spec, f, indent=1)
        B, G = TRAIN_BATCH, M.GRID
        for bits in TRAIN_BITS[arch_name]:
            name = f"train_step_{arch_name}_b{bits}"
            if want(name):
                export_one(
                    args.out_dir, name, M.make_train_step(arch, bits),
                    (
                        f32(P), f32(P), f32(S),
                        f32(B, M.IMG, M.IMG, 3), i32(B, G, G), f32(B, G, G, 4),
                        f32(B, G, G), f32(), f32(), f32(), f32(),
                    ),
                    manifest["artifacts"],
                )
        for bits in INQ_BITS[arch_name]:
            name = f"train_step_inq_{arch_name}_b{bits}"
            if want(name):
                export_one(
                    args.out_dir, name, M.make_train_step_inq(arch, bits),
                    (
                        f32(P), f32(P), f32(S),
                        f32(B, M.IMG, M.IMG, 3), i32(B, G, G), f32(B, G, G, 4),
                        f32(B, G, G), f32(P), f32(), f32(), f32(), f32(),
                    ),
                    manifest["artifacts"],
                )
        for bits in TRAIN_BITS[arch_name]:
            for bs in INFER_BATCHES:
                name = f"infer_{arch_name}_b{bits}_bs{bs}"
                if want(name):
                    export_one(
                        args.out_dir, name, M.make_infer(arch, bits),
                        (f32(P), f32(S), f32(bs, M.IMG, M.IMG, 3)),
                        manifest["artifacts"],
                    )

    for bits in (2, 3, 4, 5, 6):
        name = f"quantize_b{bits}"
        if want(name):
            export_one(
                args.out_dir, name, M.make_quantize_op(bits),
                (f32(QUANT_N), f32()),
                manifest["artifacts"],
            )

    man_path = os.path.join(args.out_dir, "manifest.json")
    existing = {}
    if only is not None and os.path.exists(man_path):
        with open(man_path) as f:
            existing = json.load(f).get("artifacts", {})
    existing.update(manifest["artifacts"])
    manifest["artifacts"] = existing
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
