# Layer-1 Pallas kernel: MXU-tiled matmul for the detection head.
#
# The R-FCN-lite head's 1x1 convolutions (cls: C->k^2(K+1), reg: C->4)
# are matmuls over the flattened spatial grid. On TPU the natural shape
# is the 128x128 MXU systolic array, so the kernel tiles M into
# BM-rows blocks held in VMEM and keeps the whole (K, N) weight tile
# resident (K = backbone width <= 128, N <= 64 here: one weight tile of
# at most 32 KiB — it stays pinned in VMEM across the grid, which is
# exactly the schedule a GPU kernel would express with a persistent
# threadblock; BlockSpec expresses it declaratively instead).
#
# interpret=True: lowers to plain HLO for the CPU PJRT runtime.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128  # M-tile: 128 rows of activations per grid step (MXU-aligned)


def _matmul_kernel(x_ref, w_ref, o_ref):
    # f32 accumulate on the MXU: jnp.dot with
    # preferred_element_type=f32 maps to one systolic pass per tile.
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _matmul_raw(x, w):
    """Tiled x @ w for 2-D f32 operands; pads M to a BM multiple."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {w.shape}"
    m_pad = (-m) % BM
    if m_pad:
        x = jnp.concatenate([x, jnp.zeros((m_pad, k), x.dtype)])
    grid = (x.shape[0] // BM,)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),  # weights pinned in VMEM
        ],
        out_specs=pl.BlockSpec((BM, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], n), jnp.float32),
        interpret=True,
    )(x, w)
    return out[:m]


@jax.custom_vjp
def matmul(x, w):
    """x @ w through the tiled Pallas kernel, with a custom VJP (the
    interpret-mode pallas_call has no autodiff rule). Both cotangents
    are themselves tiled-kernel matmuls, so fwd and bwd exercise the
    same MXU schedule:  dx = g w^T,  dw = x^T g.
    """
    return _matmul_raw(x, w)


def _matmul_fwd(x, w):
    return _matmul_raw(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    return _matmul_raw(g, w.T), _matmul_raw(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def conv1x1(x, w, bias=None):
    """1x1 convolution over NHWC ``x`` via the tiled matmul kernel.

    x: [B, H, W, Cin], w: [Cin, Cout] -> [B, H, W, Cout].
    """
    b, h, wd, cin = x.shape
    out = matmul(x.reshape(b * h * wd, cin), w)
    out = out.reshape(b, h, wd, w.shape[1])
    if bias is not None:
        out = out + bias
    return out
