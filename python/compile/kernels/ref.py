# Pure-jnp correctness oracle for the Pallas kernels.
#
# These are the ground-truth implementations of the paper's math:
#   * eq. (3): semi-analytical threshold quantization Q~ with one free
#     parameter mu (LBW-Net section 2.1),
#   * eq. (4) / Theorem 2: closed-form optimal power-of-two scale 2^s,
#   * a plain matmul used to check the MXU-tiled Pallas kernel.
#
# pytest (python/tests/) asserts the Pallas kernels match these to
# float tolerance; the rust implementation (rust/src/quant/threshold.rs)
# is cross-checked against the AOT artifact built on top of them.
import jax.numpy as jnp
import numpy as np


def levels_for_bits(b: int) -> int:
    """n = 2^{b-2}: number of nonzero magnitude levels {2^{-t}}, t=0..n-1."""
    if b < 2:
        raise ValueError(f"bit-width must be >= 2, got {b}")
    return 2 ** (b - 2)


def ref_level_index(w, mu, b: int):
    """Per-element level assignment of eq. (3).

    Returns int32 levels: t in [0, n-1] means |q| = 2^{-t}; -1 means
    pruned to zero. Uses exact comparisons (no log2) so the boundary
    behaviour is bit-reproducible across jnp / Pallas / rust:

        t = sum_{j=1..n-1} [ |w|/mu < 2^{1-j} ]      (capped at n-1)
        zero iff |w|/mu < 2^{2-n}/3

    which is algebraically identical to the case analysis in eq. (3)
    (for t in 1..n-2 the interval is [2^{-t} mu, 2^{-t+1} mu); the last
    level keeps [2^{2-n} mu / 3, 2^{2-n} mu) because its lower neighbour
    is 0, and the top level keeps everything >= mu).
    """
    n = levels_for_bits(b)
    a = jnp.abs(w)
    r = a / mu
    t = jnp.zeros(w.shape, dtype=jnp.int32)
    for j in range(1, n):
        t = t + (r < 2.0 ** (1 - j)).astype(jnp.int32)
    zero = r < (2.0 ** (2 - n)) / 3.0
    return jnp.where(zero, jnp.int32(-1), t)


def ref_qtilde(w, mu, b: int):
    """Q~ of eq. (3): sign(w) * 2^{-t}, or 0 when pruned.

    2^{-t} is built by exact halving alongside the comparison cascade so
    the result is bit-identical to the Pallas kernel and the rust
    implementation (no transcendental exp2).
    """
    n = levels_for_bits(b)
    a = jnp.abs(w)
    mag = jnp.ones(w.shape, dtype=jnp.float32)
    for j in range(1, n):
        mag = jnp.where(a < (2.0 ** (1 - j)) * mu, mag * 0.5, mag)
    t = ref_level_index(w, mu, b)
    return jnp.sign(w) * jnp.where(t < 0, 0.0, mag), t


def ref_scale_power(w, t, b: int, max_terms: int = 4):
    """Optimal scale power s~* of eq. (4) / Theorem 2.

    s = floor(log2( 4 * sum_t 2^{-t} ||W_[k_t]||_1 / (3 * sum_t k_t 2^{-2t}) ))

    Following section 2.2 we truncate the sums at the first
    ``max_terms`` levels (the tails are negligible). Returns f32 scalar
    s (an integer value); s = 0 when every weight was pruned.
    """
    n = levels_for_bits(b)
    a = jnp.abs(w)
    num = jnp.float32(0.0)
    den = jnp.float32(0.0)
    for lv in range(min(n, max_terms)):
        mask = (t == lv).astype(jnp.float32)
        num = num + (2.0 ** (-lv)) * jnp.sum(a * mask)
        den = den + (2.0 ** (-2 * lv)) * jnp.sum(mask)
    s = jnp.floor(jnp.log2(4.0 * num / (3.0 * den)))
    return jnp.where(den > 0, s, 0.0)


def ref_lbw_quantize(w, mu, b: int):
    """Full LBW quantization: W^q = 2^{s~*} Q~ (eqs. (3)+(4)).

    Returns (wq, levels_i32, s_f32). ``mu`` is the free threshold
    parameter, selected as 0.75 * ||W||_inf per layer in training.
    """
    q, t = ref_qtilde(w, mu, b)
    s = ref_scale_power(w, t, b)
    return (2.0 ** s) * q, t, s


def ref_matmul(x, w):
    """Oracle for the tiled Pallas matmul: plain f32 x @ w."""
    return jnp.matmul(x, w)


def np_lbw_quantize(w: np.ndarray, mu: float, b: int):
    """Numpy twin of ref_lbw_quantize for test-vector generation."""
    n = levels_for_bits(b)
    a = np.abs(w).astype(np.float32)
    r = a / np.float32(mu)
    t = np.zeros(w.shape, dtype=np.int32)
    for j in range(1, n):
        t += (r < np.float32(2.0 ** (1 - j))).astype(np.int32)
    t = np.where(r < np.float32((2.0 ** (2 - n)) / 3.0), -1, t)
    num = np.float32(0.0)
    den = np.float32(0.0)
    for lv in range(min(n, 4)):
        mask = t == lv
        num += np.float32(2.0 ** (-lv)) * a[mask].sum(dtype=np.float32)
        den += np.float32(2.0 ** (-2 * lv)) * np.float32(mask.sum())
    s = np.floor(np.log2(4.0 * num / (3.0 * den))) if den > 0 else 0.0
    # numpy's exp2 IS exact for integer args, but mirror the halving
    # construction anyway for uniformity across the three implementations.
    mag = np.ones(w.shape, dtype=np.float32)
    for j in range(1, n):
        mag = np.where(r < np.float32(2.0 ** (1 - j)), mag * np.float32(0.5), mag)
    mag = np.where(t < 0, np.float32(0.0), mag)
    return (np.float32(2.0**s) * np.sign(w) * mag).astype(np.float32), t, float(s)
