# Layer-1 Pallas kernel: the LBW-Net quantization projection (eq. 3).
#
# This is the paper's per-step hot spot: every training iteration each
# convolutional layer's full-precision weights are projected onto
# 2^s x {0, +-2^{1-n}, ..., +-1}. The elementwise threshold cascade of
# eq. (3) runs as a Pallas kernel tiled into VMEM-sized 1-D blocks; the
# closed-form scale of eq. (4) (cheap reductions over the level map) is
# computed in jnp on top so it fuses into the surrounding HLO.
#
# TPU adaptation (DESIGN.md section "Hardware adaptation"): the paper's
# deployment story is GPU/ASIC bit-shifts; here the *training-time*
# projection is expressed as an HBM->VMEM streamed elementwise pass,
# BLOCK=2048 f32 elements = 8 KiB per operand block (in+2 outs = 24 KiB,
# double-buffered 48 KiB, far under the ~16 MiB VMEM budget, chosen so
# the grid is long enough to pipeline).
#
# interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
# custom-calls; interpret mode lowers the kernel to plain HLO so the
# rust runtime can run the same artifact. Real-TPU perf is estimated in
# DESIGN.md / EXPERIMENTS.md section Perf.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK = 2048


def _lbw_threshold_kernel(w_ref, mu_ref, q_ref, t_ref, *, n: int):
    """Per-block eq. (3): level assignment + Q~ (unscaled sign * 2^{-t}).

    Branch-free cascade with exact power-of-two comparisons (matches
    ref.ref_level_index bit-for-bit):
        t    = sum_{j=1..n-1} [ |w| < 2^{1-j} mu ]
        zero = |w| < (2^{2-n}/3) mu
    """
    w = w_ref[...]
    mu = mu_ref[0]
    a = jnp.abs(w)
    t = jnp.zeros(w.shape, dtype=jnp.int32)
    mag = jnp.ones(w.shape, dtype=jnp.float32)
    # n is a static Python int: the cascade unrolls to n-1 vector compares
    # (n = 2^{b-2} <= 16 for b <= 6). The magnitude 2^{-t} is built by
    # exact halving alongside t (jnp.exp2 is polynomial-approximated on
    # XLA-CPU and not bit-exact for f32).
    for j in range(1, n):
        below = a < (2.0 ** (1 - j)) * mu
        t = t + below.astype(jnp.int32)
        mag = jnp.where(below, mag * 0.5, mag)
    zero = a < ((2.0 ** (2 - n)) / 3.0) * mu
    t = jnp.where(zero, jnp.int32(-1), t)
    q_ref[...] = jnp.sign(w) * jnp.where(zero, 0.0, mag)
    t_ref[...] = t


def _pad_to_block(x):
    n = x.shape[0]
    rem = (-n) % BLOCK
    if rem:
        # Pad with zeros: padded entries land in level -1 (pruned) and do
        # not perturb the eq. (4) sums (zero L1 mass, zero count).
        x = jnp.concatenate([x, jnp.zeros((rem,), x.dtype)])
    return x


def lbw_qtilde(w, mu, b: int):
    """Pallas-backed Q~ + level map of eq. (3) for a flat f32 vector."""
    n = ref.levels_for_bits(b)
    flat = _pad_to_block(w.reshape(-1))
    grid = (flat.shape[0] // BLOCK,)
    q, t = pl.pallas_call(
        functools.partial(_lbw_threshold_kernel, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),  # mu broadcast to every block
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(flat.shape, jnp.float32),
            jax.ShapeDtypeStruct(flat.shape, jnp.int32),
        ],
        interpret=True,
    )(flat, mu.reshape(1))
    size = w.size
    return q[:size].reshape(w.shape), t[:size].reshape(w.shape)


def lbw_quantize(w, mu, b: int):
    """Full LBW projection W^q = 2^{s~*} Q~ (eqs. (3)+(4)).

    ``w`` any-shape f32, ``mu`` scalar. Returns (wq, levels, s). The
    scale reductions run in jnp (they are O(4) masked sums over the
    level map and fuse with the caller); the elementwise cascade runs
    in the Pallas kernel above.
    """
    q, t = lbw_qtilde(w, mu, b)
    s = ref.ref_scale_power(w, t, b)
    return (2.0**s) * q, t, s


def lbw_quantize_layer(w, b: int, mu_ratio):
    """Layerwise projection used by training: mu = mu_ratio * ||W||_inf.

    The paper selects mu_ratio = 3/4 for b >= 4 (section 2.2); it stays
    a runtime scalar so the coordinator can sweep it (the mu-ablation
    bench).
    """
    mu = mu_ratio * jnp.max(jnp.abs(w))
    wq, _, _ = lbw_quantize(w, mu, b)
    return wq


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def lbw_quantize_ste(w, b: int, mu_ratio):
    """Straight-through projection for the projected-SGD step.

    Forward: quantized weights. Backward: identity to the
    full-precision weights — "the minibatch gradient is evaluated at
    the quantized weights, and a scaled gradient is subtracted from the
    full-precision weights" (section 2.2). custom_vjp because the
    interpret-mode pallas_call has no autodiff rule; the STE rule is
    exactly what the paper prescribes anyway.
    """
    return lbw_quantize_layer(w, b, mu_ratio)


def _ste_fwd(w, b, mu_ratio):
    return lbw_quantize_layer(w, b, mu_ratio), None


def _ste_bwd(b, _res, g):
    return g, None  # d/dw = identity; no gradient to mu_ratio


lbw_quantize_ste.defvjp(_ste_fwd, _ste_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def inq_effective(w, frozen, b: int, mu_ratio):
    """INQ effective weights (the baseline of Zhou et al. [25]): the
    `frozen` partition is pinned to its LBW-quantized value (zero
    gradient), the remainder stays full-precision and trainable.

    custom_vjp: the interpret-mode Pallas projection has no autodiff
    rule, and INQ's gradient is exactly `g * (1 - frozen)`.
    """
    wq = lbw_quantize_layer(w, b, mu_ratio)
    return frozen * wq + (1.0 - frozen) * w


def _inq_fwd(w, frozen, b, mu_ratio):
    return inq_effective(w, frozen, b, mu_ratio), frozen


def _inq_bwd(b, frozen, g):
    return g * (1.0 - frozen), jnp.zeros_like(frozen), jnp.zeros(())


inq_effective.defvjp(_inq_fwd, _inq_bwd)
