# Layer-1 Pallas kernel: position-sensitive voting (R-FCN's PS-RoI
# pooling collapsed onto the dense grid, k = 3).
#
# maps [B, G, G, K*K, C] -> scores [B, G, G, C]:
#     score[y, x, c] = mean_{(dy,dx)} maps[y+dy, x+dx, g(dy,dx), c]
# with zero contribution outside the grid.
#
# Tiling: one batch element per grid step. A full [G, G, K*K, C] slab is
# G*G*K*K*C = 8*8*9*5 f32 = 11.25 KiB — one VMEM-resident block, so the
# nine shifted reads happen entirely on-chip (the HBM->VMEM stream is
# one slab in, one [G,G,C] slab out per step). On real TPU the shifted
# reads become cheap vector moves within VMEM instead of nine strided
# HBM gathers — the same reason R-FCN's GPU kernel fused the k^2 bins.
#
# interpret=True: lowers to plain HLO for CPU PJRT (see lbw.py).
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _psvote_kernel(m_ref, o_ref, *, g: int, k: int, c: int):
    maps = m_ref[0]  # [G, G, K*K, C]
    acc = jnp.zeros((g, g, c), dtype=jnp.float32)
    # unrolled 3x3 neighbourhood: group (dy,dx) read at (y+dy, x+dx)
    padded = jnp.pad(maps, ((1, 1), (1, 1), (0, 0), (0, 0)))
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            gi = (dy + 1) * k + (dx + 1)
            acc = acc + padded[1 + dy : 1 + dy + g, 1 + dx : 1 + dx + g, gi, :]
    o_ref[0] = acc / (k * k)


def _ps_vote_raw(maps):
    b, g, g2, kk, c = maps.shape
    assert g == g2
    k = int(round(kk**0.5))
    assert k * k == kk, f"K*K groups expected, got {kk}"
    return pl.pallas_call(
        functools.partial(_psvote_kernel, g=g, k=k, c=c),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, g, g, kk, c), lambda i: (i, 0, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, g, g, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, g, g, c), jnp.float32),
        interpret=True,
    )(maps)


@jax.custom_vjp
def ps_vote(maps):
    """Pallas-backed position-sensitive vote.

    maps: [B, G, G, K*K, C] f32 -> [B, G, G, C] f32. The vote is linear,
    so the VJP is its transpose: group (dy,dx)'s cotangent is the score
    cotangent shifted by (-dy,-dx) (interpret-mode pallas_call has no
    autodiff rule; the transpose runs in jnp and fuses into the
    surrounding backward HLO).
    """
    return _ps_vote_raw(maps)


def _fwd(maps):
    return _ps_vote_raw(maps), maps.shape


def _bwd(shape, g_out):
    b, g, _, kk, c = shape
    k = int(round(kk**0.5))
    padded = jnp.pad(g_out, ((0, 0), (1, 1), (1, 1), (0, 0)))
    groups = []
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            # transpose of "read group gi at (y+dy, x+dx)": write the
            # score cotangent shifted by (-dy, -dx) into group gi
            groups.append(padded[:, 1 - dy : 1 - dy + g, 1 - dx : 1 - dx + g, :])
    d_maps = jnp.stack(groups, axis=3) / (k * k)  # [B, G, G, K*K, C]
    return (d_maps,)


ps_vote.defvjp(_fwd, _bwd)
