# Pallas PS-vote kernel vs the jnp oracle, and the INQ baseline
# train-step semantics.
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import psvote


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_psvote_kernel_matches_oracle(b, seed):
    rng = np.random.default_rng(seed)
    maps = jnp.asarray(
        rng.normal(size=(b, M.GRID, M.GRID, M.K * M.K, M.NUM_CLS)).astype(np.float32)
    )
    got = psvote.ps_vote(maps)
    want = M.ps_vote(maps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_psvote_vjp_matches_oracle_grad():
    rng = np.random.default_rng(3)
    maps = jnp.asarray(
        rng.normal(size=(2, M.GRID, M.GRID, M.K * M.K, M.NUM_CLS)).astype(np.float32)
    )
    f_k = lambda m: jnp.sum(jnp.sin(psvote.ps_vote(m)))
    f_r = lambda m: jnp.sum(jnp.sin(M.ps_vote(m)))
    gk = jax.grad(f_k)(maps)
    gr = jax.grad(f_r)(maps)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), rtol=1e-5, atol=1e-6)


def _batch(b, seed=0):
    rng = np.random.default_rng(seed)
    imgs = jnp.asarray(rng.normal(0, 1, (b, M.IMG, M.IMG, 3)).astype(np.float32))
    cls_t = jnp.asarray(rng.integers(0, M.NUM_CLS, (b, M.GRID, M.GRID)).astype(np.int32))
    box_t = jnp.asarray(rng.normal(0, 0.3, (b, M.GRID, M.GRID, 4)).astype(np.float32))
    pos = (cls_t > 0).astype(jnp.float32)
    return imgs, cls_t, box_t, pos


@pytest.fixture(scope="module")
def arch():
    return M.ARCHS["a"]


def test_inq_frozen_weights_do_not_move(arch):
    """With a frozen partition, those parameter slots must stay exactly
    at their full-precision values (INQ freezes the quantized copy; the
    shadow floats are pinned)."""
    step = jax.jit(M.make_train_step_inq(arch, 4))
    params = jnp.asarray(M.init_params(arch))
    vel = jnp.zeros_like(params)
    state = jnp.asarray(M.init_state(arch))
    imgs, cls_t, box_t, pos = _batch(4)
    # freeze the first conv layer entirely
    e = M.param_spec(arch)[0]
    frozen = jnp.zeros_like(params).at[e.offset : e.offset + e.size].set(1.0)
    hyper = (jnp.float32(0.05), jnp.float32(0.9), jnp.float32(0.75), jnp.float32(0.0))
    p, v, s, loss, _, _ = step(params, vel, state, imgs, cls_t, box_t, pos, frozen, *hyper)
    frozen_np = np.asarray(frozen) > 0
    np.testing.assert_array_equal(np.asarray(p)[frozen_np], np.asarray(params)[frozen_np])
    assert not np.array_equal(np.asarray(p)[~frozen_np], np.asarray(params)[~frozen_np])
    assert np.isfinite(float(loss))


def test_inq_all_frozen_trains_nothing_but_bn(arch):
    step = jax.jit(M.make_train_step_inq(arch, 4))
    params = jnp.asarray(M.init_params(arch, seed=2))
    vel = jnp.zeros_like(params)
    state = jnp.asarray(M.init_state(arch))
    imgs, cls_t, box_t, pos = _batch(4, seed=5)
    frozen = jnp.ones_like(params)
    hyper = (jnp.float32(0.05), jnp.float32(0.9), jnp.float32(0.75), jnp.float32(0.0))
    p, _, s, loss, _, _ = step(params, vel, state, imgs, cls_t, box_t, pos, frozen, *hyper)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(params))
    # BN running stats still update (they are state, not params)
    assert not np.array_equal(np.asarray(s), np.asarray(state))


def test_inq_loss_decreases_over_steps(arch):
    step = jax.jit(M.make_train_step_inq(arch, 4))
    params = jnp.asarray(M.init_params(arch, seed=3))
    vel = jnp.zeros_like(params)
    state = jnp.asarray(M.init_state(arch))
    imgs, cls_t, box_t, pos = _batch(4, seed=7)
    frozen = jnp.zeros_like(params)  # phase 0: nothing frozen yet
    hyper = (jnp.float32(0.02), jnp.float32(0.9), jnp.float32(0.75), jnp.float32(1e-5))
    losses = []
    for _ in range(5):
        params, vel, state, loss, _, _ = step(
            params, vel, state, imgs, cls_t, box_t, pos, frozen, *hyper
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
