# AOT exporter contract tests: HLO text emission, spec JSON layout, and
# signature stability across bit-widths.
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M


def test_to_hlo_text_emits_parseable_text():
    f = lambda x, y: (jnp.matmul(x, y) + 1.0,)
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(f).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[4,4]" in text
    # text format, not binary proto
    assert text.isprintable() or "\n" in text


def test_spec_json_matches_model(tmp_path):
    for arch_name, arch in M.ARCHS.items():
        spec = {
            "arch": arch_name,
            "num_params": M.num_params(arch),
            "num_state": M.num_state(arch),
            "params": aot._spec_json(M.param_spec(arch)),
            "state": aot._spec_json(M.state_spec(arch)),
        }
        text = json.dumps(spec)
        loaded = json.loads(text)
        total = sum(e["size"] for e in loaded["params"])
        assert total == loaded["num_params"]
        offsets_ok = True
        off = 0
        for e in loaded["params"]:
            offsets_ok &= e["offset"] == off
            off += e["size"]
        assert offsets_ok


def test_train_step_signature_uniform_across_bits():
    """The rust trainer feeds the same 11 inputs regardless of
    bit-width; keep_unused must preserve unused hyper scalars."""
    arch = M.ARCHS["a"]
    P, S = M.num_params(arch), M.num_state(arch)
    B, G = 2, M.GRID

    def args():
        return (
            jax.ShapeDtypeStruct((P,), jnp.float32),
            jax.ShapeDtypeStruct((P,), jnp.float32),
            jax.ShapeDtypeStruct((S,), jnp.float32),
            jax.ShapeDtypeStruct((B, M.IMG, M.IMG, 3), jnp.float32),
            jax.ShapeDtypeStruct((B, G, G), jnp.int32),
            jax.ShapeDtypeStruct((B, G, G, 4), jnp.float32),
            jax.ShapeDtypeStruct((B, G, G), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        )

    for bits in (6, 32):
        lowered = jax.jit(M.make_train_step(arch, bits), keep_unused=True).lower(*args())
        text = aot.to_hlo_text(lowered)
        # 11 parameters in the entry computation
        entry = [l for l in text.splitlines() if "ENTRY" in l]
        assert entry, text[:200]
        assert entry[0].count("parameter") == 11 or text.count("parameter(") >= 11


@pytest.mark.parametrize("bits", [4, 6])
def test_quantize_op_matches_ref(bits):
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(0, 0.05, 512).astype(np.float32))
    mu = jnp.float32(0.75 * float(jnp.max(jnp.abs(w))))
    op = M.make_quantize_op(bits)
    wq, t, s = jax.jit(op)(w, mu)
    from compile.kernels import ref

    wq_r, t_r, s_r = ref.ref_lbw_quantize(w, mu, bits)
    np.testing.assert_array_equal(np.asarray(wq), np.asarray(wq_r))
    np.testing.assert_array_equal(np.asarray(t), np.asarray(t_r))
