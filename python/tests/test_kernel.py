# Kernel-vs-reference correctness: the CORE L1 signal.
#
# The Pallas LBW quantizer must match the pure-jnp oracle bit-for-bit
# (both use the exact-comparison cascade; no transcendentals), and the
# tiled matmul must match jnp.matmul to f32 tolerance. hypothesis
# sweeps shapes, dtyped ranges, bit-widths, and mu ratios.
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import lbw, matmul, ref

BITS = [2, 3, 4, 5, 6]


def _rand_w(n, seed, scale=0.05):
    rng = np.random.default_rng(seed)
    return rng.normal(0.0, scale, n).astype(np.float32)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("n", [1, 7, 2048, 2049, 5000])
def test_pallas_matches_ref(bits, n):
    w = jnp.asarray(_rand_w(n, seed=n * 31 + bits))
    mu = 0.75 * jnp.max(jnp.abs(w))
    wq_k, t_k = lbw.lbw_qtilde(w, mu, bits)
    wq_r, t_r = ref.ref_qtilde(w, mu, bits)
    np.testing.assert_array_equal(np.asarray(t_k), np.asarray(t_r))
    np.testing.assert_array_equal(np.asarray(wq_k), np.asarray(wq_r))


@pytest.mark.parametrize("bits", BITS)
def test_full_quantize_matches_numpy(bits):
    w = _rand_w(4096, seed=bits)
    mu = float(0.75 * np.abs(w).max())
    wq_k, t_k, s_k = lbw.lbw_quantize(jnp.asarray(w), jnp.float32(mu), bits)
    wq_n, t_n, s_n = ref.np_lbw_quantize(w, mu, bits)
    np.testing.assert_array_equal(np.asarray(t_k), t_n)
    assert float(s_k) == s_n
    np.testing.assert_array_equal(np.asarray(wq_k), wq_n)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 3000),
    bits=st.sampled_from(BITS),
    scale=st.floats(1e-3, 10.0),
    ratio=st.floats(0.1, 1.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_quantized_values_are_powers_of_two(n, bits, scale, ratio, seed):
    """Every quantized weight is 0 or +-2^k; level map consistent with
    the output value; mu sweep included (the free parameter)."""
    w = _rand_w(n, seed, scale)
    if np.abs(w).max() == 0.0:
        return
    mu = np.float32(ratio * np.abs(w).max())
    wq, t, s = lbw.lbw_quantize(jnp.asarray(w), jnp.asarray(mu), bits)
    wq, t, s = np.asarray(wq), np.asarray(t), float(s)
    nlev = ref.levels_for_bits(bits)
    assert t.min() >= -1 and t.max() < nlev
    zero = t == -1
    assert (wq[zero] == 0).all()
    nz = wq[~zero]
    if nz.size:
        m = np.frexp(np.abs(nz))[0]  # mantissa of a power of two is 0.5
        np.testing.assert_array_equal(m, np.full_like(m, 0.5))
        expected = np.exp2(s - t[~zero].astype(np.float64)) * np.sign(w[~zero])
        np.testing.assert_allclose(nz, expected, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    bits=st.sampled_from(BITS),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_projection_no_worse_than_naive_scale(bits, seed):
    """The eq.(4) scale must beat (or tie) its power-of-two neighbours:
    floor-to-nearest-pow2 of the unconstrained optimum is optimal among
    integer s for the fixed level assignment."""
    w = _rand_w(1024, seed)
    mu = np.float32(0.75 * np.abs(w).max())
    wq, t, s = lbw.lbw_quantize(jnp.asarray(w), jnp.asarray(mu), bits)
    wq, t = np.asarray(wq), np.asarray(t)
    q = np.where(t < 0, 0.0, np.exp2(-np.maximum(t, 0).astype(np.float64))) * np.sign(w)
    err = ((wq - w) ** 2).sum()
    for ds in (-1, 1):
        alt = np.exp2(float(s) + ds) * q
        assert err <= ((alt - w) ** 2).sum() + 1e-6


@pytest.mark.parametrize("bits", BITS)
def test_ternary_special_case_structure(bits):
    """b=2 must produce exactly {0, +-2^s}; b>2 produces at most
    2^{b-2} distinct magnitudes (paper: 2^{b-1}+1 candidate values)."""
    w = _rand_w(8192, seed=7)
    mu = np.float32(0.75 * np.abs(w).max())
    wq = np.asarray(lbw.lbw_quantize(jnp.asarray(w), jnp.asarray(mu), bits)[0])
    mags = np.unique(np.abs(wq[wq != 0]))
    assert len(mags) <= ref.levels_for_bits(bits)
    if bits == 2:
        assert len(mags) <= 1


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 300),
    k=st.sampled_from([16, 64, 128]),
    n=st.sampled_from([4, 45, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(matmul.matmul(x, w)),
        np.asarray(ref.ref_matmul(x, w)),
        rtol=1e-4, atol=1e-4,
    )


def test_matmul_grad_matches_jnp():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(130, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 45)).astype(np.float32))
    f_k = lambda x, w: jnp.sum(jnp.sin(matmul.matmul(x, w)))
    f_r = lambda x, w: jnp.sum(jnp.sin(jnp.matmul(x, w)))
    gx_k, gw_k = jax.grad(f_k, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_r, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_r), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw_k), np.asarray(gw_r), rtol=1e-4, atol=1e-4)


def test_ste_gradient_is_identity():
    w = jnp.asarray(_rand_w(3000, seed=11))
    g = jax.grad(lambda w: jnp.sum(lbw.lbw_quantize_ste(w, 6, jnp.float32(0.75)) * 3.0))(w)
    np.testing.assert_allclose(np.asarray(g), np.full(3000, 3.0, np.float32))


def test_mu_zero_edge_case():
    """All-zero weight vector: everything prunes, s falls back to 0."""
    w = jnp.zeros(128, jnp.float32)
    wq, t, s = lbw.lbw_quantize(w, jnp.float32(1.0), 6)
    assert (np.asarray(wq) == 0).all() and (np.asarray(t) == -1).all()
    assert float(s) == 0.0
