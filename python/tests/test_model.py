# L2 model tests: shapes, spec consistency, training dynamics, and the
# quantization-in-the-loop behaviour of the projected-SGD step.
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model as M
from compile.kernels import ref


@pytest.fixture(scope="module", params=["a", "b"])
def arch(request):
    return M.ARCHS[request.param]


def _batch(b, seed=0):
    rng = np.random.default_rng(seed)
    imgs = jnp.asarray(rng.normal(0, 1, (b, M.IMG, M.IMG, 3)).astype(np.float32))
    cls_t = jnp.asarray(rng.integers(0, M.NUM_CLS, (b, M.GRID, M.GRID)).astype(np.int32))
    box_t = jnp.asarray(rng.normal(0, 0.3, (b, M.GRID, M.GRID, 4)).astype(np.float32))
    pos = (cls_t > 0).astype(jnp.float32)
    return imgs, cls_t, box_t, pos


def test_param_spec_contiguous(arch):
    off = 0
    for e in M.param_spec(arch):
        assert e.offset == off
        assert e.size == int(np.prod(e.shape))
        off += e.size
    assert off == M.num_params(arch)
    off = 0
    for e in M.state_spec(arch):
        assert e.offset == off
        off += e.size
    assert off == M.num_state(arch)


def test_every_conv_is_quantized(arch):
    for e in M.param_spec(arch):
        assert e.quantize == (e.kind == "conv"), e.name


def test_unflatten_roundtrip(arch):
    spec = M.param_spec(arch)
    flat = jnp.asarray(M.init_params(arch, seed=1))
    d = M.unflatten(flat, spec)
    assert set(d.keys()) == {e.name for e in spec}
    np.testing.assert_array_equal(np.asarray(M.flatten_dict(d, spec)), np.asarray(flat))


def test_forward_shapes(arch):
    pd = M.unflatten(jnp.asarray(M.init_params(arch)), M.param_spec(arch))
    sd = M.unflatten(jnp.asarray(M.init_state(arch)), M.state_spec(arch))
    imgs, *_ = _batch(2)
    cls_logits, reg, new_sd = M.forward(pd, sd, imgs, arch, 32, jnp.float32(0.75), train=True)
    assert cls_logits.shape == (2, M.GRID, M.GRID, M.NUM_CLS)
    assert reg.shape == (2, M.GRID, M.GRID, 4)
    assert set(new_sd.keys()) == {e.name for e in M.state_spec(arch)}


@pytest.mark.parametrize("bits", [4, 6, 32])
def test_train_step_reduces_loss(arch, bits):
    """A few projected-SGD steps on one fixed batch must reduce the loss
    — quantization in the loop must not break learning."""
    step = jax.jit(M.make_train_step(arch, bits))
    params = jnp.asarray(M.init_params(arch))
    vel = jnp.zeros_like(params)
    state = jnp.asarray(M.init_state(arch))
    imgs, cls_t, box_t, pos = _batch(4)
    hyper = (jnp.float32(0.02), jnp.float32(0.9), jnp.float32(0.75), jnp.float32(1e-5))
    losses = []
    for _ in range(6):
        params, vel, state, loss, _, _ = step(
            params, vel, state, imgs, cls_t, box_t, pos, *hyper
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    assert np.isfinite(losses).all()


def test_infer_uses_quantized_weights():
    """Perturbing a conv weight *below* the quantization resolution must
    not change the low-bit inference output (weights really are
    projected), while the fp32 path does change."""
    arch = M.ARCHS["a"]
    params = jnp.asarray(M.init_params(arch, seed=3))
    state = jnp.asarray(M.init_state(arch))
    imgs, *_ = _batch(1, seed=5)
    infer4 = jax.jit(M.make_infer(arch, 4))
    infer32 = jax.jit(M.make_infer(arch, 32))
    e = M.param_spec(arch)[0]  # stem conv
    w = params[e.offset : e.offset + e.size]
    eps = 1e-6 * float(jnp.abs(w).max())
    bumped = params.at[e.offset].add(eps)
    p4a, _ = infer4(params, state, imgs)
    p4b, _ = infer4(bumped, state, imgs)
    p32a, _ = infer32(params, state, imgs)
    p32b, _ = infer32(bumped, state, imgs)
    np.testing.assert_array_equal(np.asarray(p4a), np.asarray(p4b))
    assert not np.array_equal(np.asarray(p32a), np.asarray(p32b))


def test_train_weights_land_on_grid_after_quantize():
    """Quantizing the trained full-precision weights yields only
    {0, +-2^k} — checked through the infer graph's internal projection
    by re-projecting externally and comparing."""
    arch = M.ARCHS["a"]
    params = jnp.asarray(M.init_params(arch, seed=4))
    for e in M.param_spec(arch):
        if not e.quantize:
            continue
        w = params[e.offset : e.offset + e.size]
        mu = 0.75 * jnp.max(jnp.abs(w))
        wq, t, s = ref.ref_lbw_quantize(w, mu, 6)
        nz = np.asarray(wq)[np.asarray(t) >= 0]
        if nz.size:
            m, _ = np.frexp(np.abs(nz))
            np.testing.assert_array_equal(m, np.full_like(m, 0.5))


def test_ps_vote_center_object():
    """A delta placed in group g=(dy,dx) at cell (y+dy, x+dx) votes for
    cell (y,x): position-sensitivity sanity."""
    maps = jnp.zeros((1, M.GRID, M.GRID, M.K * M.K, M.NUM_CLS))
    y, x = 3, 4
    dy, dx = 1, -1
    g = (dy + 1) * M.K + (dx + 1)
    maps = maps.at[0, y + dy, x + dx, g, 2].set(9.0)
    out = M.ps_vote(maps)
    assert float(out[0, y, x, 2]) == pytest.approx(1.0)  # 9.0 / 9 groups
    # no other cell receives more
    assert float(out[0, y, x, 2]) == pytest.approx(float(jnp.max(out)))


def test_loss_ignores_negative_boxes():
    """Box loss must be masked to positive cells only."""
    b = 2
    cls_logits = jnp.zeros((b, M.GRID, M.GRID, M.NUM_CLS))
    reg = jnp.ones((b, M.GRID, M.GRID, 4)) * 100.0
    cls_t = jnp.zeros((b, M.GRID, M.GRID), jnp.int32)
    box_t = jnp.zeros((b, M.GRID, M.GRID, 4))
    pos = jnp.zeros((b, M.GRID, M.GRID))
    _, box_loss = M.detection_loss(cls_logits, reg, cls_t, box_t, pos)
    assert float(box_loss) == 0.0


def test_bn_state_updates_in_train_only():
    arch = M.ARCHS["a"]
    step = jax.jit(M.make_train_step(arch, 32))
    params = jnp.asarray(M.init_params(arch))
    vel = jnp.zeros_like(params)
    state = jnp.asarray(M.init_state(arch))
    imgs, cls_t, box_t, pos = _batch(4, seed=9)
    _, _, new_state, *_ = step(
        params, vel, state, imgs, cls_t, box_t, pos,
        jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.75), jnp.float32(0.0),
    )
    assert not np.array_equal(np.asarray(new_state), np.asarray(state))
    infer = jax.jit(M.make_infer(arch, 32))
    infer(params, state, imgs)  # eval path must not require state update
