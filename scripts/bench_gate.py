#!/usr/bin/env python3
"""Bench-trajectory regression gate for BENCH_serve.json.

Parses the file `make bench-smoke` just wrote and FAILS (exit 1) when
the serving trajectory regresses below the floors the ROADMAP commits
to:

  * planned/naive img/s ratio at 1 shard, 1 thread, fixed 2ms window
    (closed loop) must stay >= PLANNED_RATIO_MIN for every engine;
  * planned 4-thread/1-thread img/s speedup at 1 shard must stay
    >= THREAD_RATIO_MIN for every engine;
  * every `"shards": "auto"` row must record >= 1 scale-up AND >= 1
    drain (an elastic supervisor that never scales is a regression);
  * when the sweep ran with a detected SIMD backend (`"simd": "on"`
    rows present), the planned shift6 simd/scalar img/s ratio at 1
    shard, 1 thread must stay >= SIMD_RATIO_MIN. Skipped entirely on
    hosts without AVX2/NEON (no "on" rows) and on pre-SIMD bench files
    (rows without a "simd" field are implicitly "off"); but "on" rows
    WITHOUT the forced-scalar baseline row are a failure — the sweep
    lost its denominator;
  * fault rows (`"faults"` field present): no row may record
    `crashes > 0` together with `lost > 0` — a caught panic must never
    cost a client its response; crashes without respawns mean the
    supervisor failed to replace a dead generation; and a `"storm"`
    row with zero crashes means the injection harness never fired.
    Rows carrying a `"faults"` marker other than `"none"` are excluded
    from the healthy closed-loop baselines above;
  * multi-model registry rows (`"models"` field present) sit outside
    the closed-loop baselines and carry their own laws: a hot-swap row
    (`"swaps"` present) with `lost > 0` fails — a checkpoint swap must
    never cost a client its response — and one with `swaps < 1` means
    the swap harness never fired; a tenant row (`"tenant_mix"`
    present) where any listed tenant recorded zero dequeues fails —
    the weighted-fair arbiter must never starve a class, including
    weight-0 background tenants.

Floors are overridable via env (GATE_PLANNED_RATIO_MIN,
GATE_THREAD_RATIO_MIN, GATE_SIMD_RATIO_MIN) so a deliberate trade-off
can be landed without editing this script.

Usage:
    scripts/bench_gate.py [BENCH_serve.json]
    scripts/bench_gate.py --self-test

--self-test feeds the gate doctored rows (a collapsed planned/naive
ratio, a flat thread speedup, an eventless autoscale row) and asserts
each one is caught, then feeds a healthy set and asserts it passes —
proof in CI that the gate *can* fail before it is trusted to pass.
"""

import json
import os
import sys

PLANNED_RATIO_MIN = float(os.environ.get("GATE_PLANNED_RATIO_MIN", "2.0"))
THREAD_RATIO_MIN = float(os.environ.get("GATE_THREAD_RATIO_MIN", "1.5"))
SIMD_RATIO_MIN = float(os.environ.get("GATE_SIMD_RATIO_MIN", "1.3"))
ENGINES = ("float", "shift6")


def closed_loop_rate(rows, executor, engine, threads, simd=None):
    """img/s of the classic closed-loop cell (1 shard, fixed 2ms).

    `simd=None` matches any backend (first row wins — the sweep emits
    the detected-backend cells first, so the pre-SIMD checks keep
    comparing the production configuration); `"on"`/`"off"` pins the
    kernel backend, with rows from before the SIMD PR counting as
    `"off"`.
    """
    for r in rows:
        if (
            r.get("executor") == executor
            and r.get("engine") == engine
            and r.get("shards") == 1
            and r.get("threads") == threads
            and r.get("window") == "fixed"
            and r.get("batch_window_ms") == 2
            and "load" not in r
            # trained-checkpoint cells are a separate dimension; the
            # closed-loop baselines compare synth rows only
            and r.get("checkpoint") in (None, "synth")
            # chaos cells measure the fault domain, not the engine —
            # only fault-free rows are baseline material
            and r.get("faults") in (None, "none")
            # multi-model registry cells route through tenant queues
            # and (for swap rows) a mid-run generation turnover — not
            # the single-model configuration the baselines compare
            and "models" not in r
            and (simd is None or r.get("simd", "off") == simd)
        ):
            return r.get("imgs_per_s", 0.0)
    return None


def check(rows):
    """Return a list of failure strings (empty = gate passes)."""
    failures = []
    for engine in ENGINES:
        planned = closed_loop_rate(rows, "planned", engine, 1)
        naive = closed_loop_rate(rows, "naive", engine, 1)
        if planned is None or naive is None:
            failures.append(
                f"{engine}: missing closed-loop planned/naive 1-shard rows "
                "(did the sweep run?)"
            )
        elif naive <= 0 or planned / naive < PLANNED_RATIO_MIN:
            ratio = planned / naive if naive > 0 else float("nan")
            failures.append(
                f"{engine}: planned/naive single-shard ratio {ratio:.2f}x "
                f"< {PLANNED_RATIO_MIN}x floor"
            )
        t1 = closed_loop_rate(rows, "planned", engine, 1)
        t4 = closed_loop_rate(rows, "planned", engine, 4)
        if t1 is None or t4 is None:
            failures.append(f"{engine}: missing planned 1-thread/4-thread rows")
        elif t1 <= 0 or t4 / t1 < THREAD_RATIO_MIN:
            ratio = t4 / t1 if t1 > 0 else float("nan")
            failures.append(
                f"{engine}: planned 4-thread/1-thread speedup {ratio:.2f}x "
                f"< {THREAD_RATIO_MIN}x floor"
            )
    # simd/scalar ratio on the shift engine — the ISSUE-7 deployment
    # claim. Gated only when the sweep actually ran a SIMD backend.
    simd_on = closed_loop_rate(rows, "planned", "shift6", 1, simd="on")
    if simd_on is not None:
        simd_off = closed_loop_rate(rows, "planned", "shift6", 1, simd="off")
        if simd_off is None:
            failures.append(
                "shift6: simd-on rows present but the forced-scalar baseline "
                "row (planned, 1 shard, 1 thread, simd off) is missing — "
                "the ratio has no denominator"
            )
        elif simd_off <= 0 or simd_on / simd_off < SIMD_RATIO_MIN:
            ratio = simd_on / simd_off if simd_off > 0 else float("nan")
            failures.append(
                f"shift6: planned simd/scalar single-shard ratio {ratio:.2f}x "
                f"< {SIMD_RATIO_MIN}x floor"
            )
    for r in rows:
        if "faults" in r:
            crashes = r.get("crashes", 0)
            respawns = r.get("respawns", 0)
            lost = r.get("lost", 0)
            label = f"fault row ({r.get('engine')}, faults {r.get('faults')})"
            if crashes > 0 and lost > 0:
                failures.append(
                    f"{label}: {crashes} crash(es) with {lost} lost "
                    "response(s) — a caught panic must never cost a client "
                    "its response"
                )
            if crashes > 0 and respawns < 1:
                failures.append(
                    f"{label}: {crashes} crash(es) but 0 respawns — the "
                    "pool must replace crashed generations"
                )
            if r.get("faults") == "storm" and crashes < 1:
                failures.append(
                    f"{label}: storm row recorded no crashes — the "
                    "fault-injection harness never fired"
                )
    for r in rows:
        if "models" not in r:
            continue
        label = f"registry row (models {r.get('models')})"
        if "swaps" in r:
            swaps = r.get("swaps", 0)
            lost = r.get("lost", 0)
            if lost > 0:
                failures.append(
                    f"{label}: {lost} lost response(s) across {swaps} hot "
                    "swap(s) — a checkpoint swap must never cost a client "
                    "its response"
                )
            if swaps < 1:
                failures.append(
                    f"{label}: swap row recorded no swaps — the "
                    "hot-swap harness never fired"
                )
        if "tenant_mix" in r:
            counts = r.get("tenant_counts", [])
            if not counts:
                failures.append(
                    f"{label}: tenant row (mix {r.get('tenant_mix')}) "
                    "carries no dequeue counts"
                )
            for t, n in enumerate(counts):
                if n < 1:
                    failures.append(
                        f"{label}: tenant {t} (mix {r.get('tenant_mix')}) "
                        "recorded zero dequeues — the weighted-fair "
                        "arbiter starved a listed class"
                    )
    for r in rows:
        if r.get("shards") == "auto":
            ups = r.get("scale_ups", 0)
            downs = r.get("scale_downs", 0)
            if ups < 1 or downs < 1:
                failures.append(
                    f"autoscale row ({r.get('engine')}, load {r.get('load')}): "
                    f"{ups} scale-up(s) / {downs} drain(s) — the supervisor "
                    "must both spawn under bursts and drain in the gaps"
                )
    return failures


def healthy_rows():
    base = {"window": "fixed", "batch_window_ms": 2}
    rows = []
    for engine in ENGINES:
        rows += [
            dict(base, executor="planned", engine=engine, shards=1, threads=1, imgs_per_s=300.0,
                 simd="on"),
            dict(base, executor="naive", engine=engine, shards=1, threads=1, imgs_per_s=100.0,
                 simd="off"),
            dict(base, executor="planned", engine=engine, shards=1, threads=4, imgs_per_s=600.0,
                 simd="on"),
        ]
    # the forced-scalar baseline the simd gate divides by (300/200 = 1.5x)
    rows.append(
        dict(base, executor="planned", engine="shift6", shards=1, threads=1, imgs_per_s=200.0,
             simd="off")
    )
    rows.append(
        dict(
            base,
            executor="planned",
            engine="shift6",
            shards="auto",
            threads=1,
            load="bursty",
            scale_ups=2,
            scale_downs=1,
        )
    )
    # the fault sweep's twin rows: fault-free control + panic storm
    # (crashes happened, every one respawned, nothing lost)
    rows.append(
        dict(base, executor="planned", engine="shift6", shards=1, threads=1,
             imgs_per_s=290.0, simd="on", faults="none", crashes=0,
             respawns=0, lost=0)
    )
    rows.append(
        dict(base, executor="planned", engine="shift6", shards=1, threads=1,
             imgs_per_s=240.0, simd="on", faults="storm", crashes=3,
             respawns=3, lost=0)
    )
    # the multi-model registry rows: a mixed-tenant cell (every listed
    # tenant saw dequeues) and a hot-swap cell (swaps landed, nothing
    # lost)
    rows.append(
        dict(base, executor="planned", engine="multi", shards=2, threads=1,
             imgs_per_s=250.0, simd="on", models="hi=shift6+lo=shift2",
             resident_weight_bytes=1000, tenant_mix="3:1",
             tenant_counts=[36, 12], tenant_p95_ms=[8.0, 14.0])
    )
    rows.append(
        dict(base, executor="planned", engine="shift6", shards=2, threads=1,
             imgs_per_s=260.0, simd="on", models="m6=shift6",
             resident_weight_bytes=750, swaps=2, lost=0)
    )
    return rows


def self_test():
    assert check(healthy_rows()) == [], "healthy trajectory must pass the gate"

    # injected regression 1: planned/naive ratio collapses to ~1.1x
    doctored = healthy_rows()
    for r in doctored:
        if r["executor"] == "naive" and r["engine"] == "shift6":
            r["imgs_per_s"] = 280.0
    fails = check(doctored)
    assert any("planned/naive" in f and "shift6" in f for f in fails), fails

    # injected regression 2: thread speedup collapses to 1.0x
    doctored = healthy_rows()
    for r in doctored:
        if r["executor"] == "planned" and r["threads"] == 4 and r["engine"] == "float":
            r["imgs_per_s"] = 300.0
    fails = check(doctored)
    assert any("4-thread/1-thread" in f and "float" in f for f in fails), fails

    # injected regression 3: the elastic supervisor never drains
    doctored = healthy_rows()
    for r in doctored:
        if r.get("shards") == "auto":
            r["scale_downs"] = 0
    fails = check(doctored)
    assert any("autoscale" in f for f in fails), fails

    # injected regression 4: the sweep silently lost its naive rows
    doctored = [r for r in healthy_rows() if r["executor"] != "naive"]
    fails = check(doctored)
    assert any("missing" in f for f in fails), fails

    # injected regression 5: the simd/scalar ratio collapses to ~1.07x
    doctored = healthy_rows()
    for r in doctored:
        if r.get("simd") == "off" and r["executor"] == "planned" and r["engine"] == "shift6":
            r["imgs_per_s"] = 280.0
    fails = check(doctored)
    assert any("simd/scalar" in f for f in fails), fails

    # injected regression 6: simd-on rows without the scalar baseline
    doctored = [
        r
        for r in healthy_rows()
        if not (r.get("simd") == "off" and r["executor"] == "planned")
    ]
    fails = check(doctored)
    assert any("no denominator" in f for f in fails), fails

    # injected regression 7: the crash storm lost responses
    doctored = healthy_rows()
    for r in doctored:
        if r.get("faults") == "storm":
            r["lost"] = 2
    fails = check(doctored)
    assert any("lost" in f for f in fails), fails

    # injected regression 8: crashes happened but nothing respawned
    doctored = healthy_rows()
    for r in doctored:
        if r.get("faults") == "storm":
            r["respawns"] = 0
    fails = check(doctored)
    assert any("0 respawns" in f for f in fails), fails

    # injected regression 9: the storm row shows the harness never fired
    doctored = healthy_rows()
    for r in doctored:
        if r.get("faults") == "storm":
            r["crashes"] = 0
            r["respawns"] = 0
    fails = check(doctored)
    assert any("never fired" in f for f in fails), fails

    # injected regression 10: the hot swap lost a response
    doctored = healthy_rows()
    for r in doctored:
        if "swaps" in r:
            r["lost"] = 1
    fails = check(doctored)
    assert any("hot" in f and "swap" in f for f in fails), fails

    # injected regression 11: the weighted-fair arbiter starved a tenant
    doctored = healthy_rows()
    for r in doctored:
        if "tenant_counts" in r:
            r["tenant_counts"] = [48, 0]
    fails = check(doctored)
    assert any("starved" in f for f in fails), fails

    # injected regression 12: the swap harness never fired
    doctored = healthy_rows()
    for r in doctored:
        if "swaps" in r:
            r["swaps"] = 0
    fails = check(doctored)
    assert any("hot-swap harness" in f for f in fails), fails

    # a pre-registry bench file (no "models" rows at all) must still
    # pass: the registry gate only judges rows carrying the marker
    premodel = [r for r in healthy_rows() if "models" not in r]
    assert check(premodel) == [], "pre-registry trajectory must pass (gate skipped)"

    # a pre-fault bench file (no "faults" rows at all) must still pass:
    # the fault gate only judges rows that carry the marker
    prefault = [r for r in healthy_rows() if "faults" not in r]
    assert check(prefault) == [], "pre-fault trajectory must pass (gate skipped)"

    # a pre-SIMD bench file (no "simd" fields at all) must still pass:
    # the simd gate skips, the legacy gates keep working
    stripped = []
    for r in healthy_rows():
        r = dict(r)
        r.pop("simd", None)
        stripped.append(r)
    assert check(stripped) == [], "simd-less trajectory must pass (gate skipped)"

    print("bench_gate self-test: all injected regressions caught, healthy set passes")


def main(argv):
    if len(argv) > 1 and argv[1] == "--self-test":
        self_test()
        return 0
    path = argv[1] if len(argv) > 1 else "BENCH_serve.json"
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get("rows", [])
    failures = check(rows)
    if failures:
        print(f"bench gate FAILED on {path}:")
        for f in failures:
            print(f"  - {f}")
        return 1
    simd_note = (
        f"simd/scalar >= {SIMD_RATIO_MIN}x"
        if closed_loop_rate(rows, "planned", "shift6", 1, simd="on") is not None
        else "simd gate skipped (no simd-on rows)"
    )
    fault_note = (
        "fault rows lose nothing"
        if any("faults" in r for r in rows)
        else "fault gate skipped (no fault rows)"
    )
    print(
        f"bench gate passed on {path}: planned/naive >= {PLANNED_RATIO_MIN}x, "
        f"4t/1t >= {THREAD_RATIO_MIN}x, {simd_note}, autoscale rows show "
        f"scale events, {fault_note}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
