#!/usr/bin/env python3
"""Bench-trajectory regression gate for BENCH_serve.json.

Parses the file `make bench-smoke` (now a lab-driven run: `repro lab
run ci-smoke --only serve`) just wrote and FAILS (exit 1) when the
serving trajectory regresses below the floors the ROADMAP commits to:

  * planned/naive img/s ratio at 1 shard, 1 thread, fixed 2ms window
    (closed loop) must stay >= PLANNED_RATIO_MIN for every engine;
  * planned 4-thread/1-thread img/s speedup at 1 shard must stay
    >= THREAD_RATIO_MIN for every engine;
  * every `"shards": "auto"` row must record >= 1 scale-up AND >= 1
    drain (an elastic supervisor that never scales is a regression);
  * when the sweep ran with a detected SIMD backend (`"simd": "on"`
    rows present), the planned shift6 simd/scalar img/s ratio at 1
    shard, 1 thread must stay >= SIMD_RATIO_MIN. Skipped entirely on
    hosts without AVX2/NEON (no "on" rows) and on pre-SIMD bench files
    (rows without a "simd" field are implicitly "off"); but "on" rows
    WITHOUT the forced-scalar baseline row are a failure — the sweep
    lost its denominator;
  * fault rows (`"faults"` field present): no row may record
    `crashes > 0` together with `lost > 0` — a caught panic must never
    cost a client its response; crashes without respawns mean the
    supervisor failed to replace a dead generation; and a `"storm"`
    row with zero crashes means the injection harness never fired.
    Rows carrying a `"faults"` marker other than `"none"` are excluded
    from the healthy closed-loop baselines above;
  * multi-model registry rows (`"models"` field present) sit outside
    the closed-loop baselines and carry their own laws: a hot-swap row
    (`"swaps"` present) with `lost > 0` fails — a checkpoint swap must
    never cost a client its response — and one with `swaps < 1` means
    the swap harness never fired; a tenant row (`"tenant_mix"`
    present) where any listed tenant recorded zero dequeues fails —
    the weighted-fair arbiter must never starve a class, including
    weight-0 background tenants.

Variance-aware mode: a lab-exported document carries a `"tables"` key
with per-cell mean/std/min/max over repeats. When present, the ratio
floors above compare CELL MEANS and only fail when the shortfall
exceeds the pooled standard deviation of the two cells — a ratio
nominally below the floor but within measurement noise does not fail
CI, and a ratio clearly below it still does. The absolute invariants
(autoscale events, fault/swap/tenant laws) remain per-trial checks on
the flat rows: they must hold on EVERY repeat, not on average. A flat
pre-lab document (no `"tables"`) falls back to the strict single-shot
comparisons, unchanged.

Floors are overridable via env (GATE_PLANNED_RATIO_MIN,
GATE_THREAD_RATIO_MIN, GATE_SIMD_RATIO_MIN) so a deliberate trade-off
can be landed without editing this script.

Usage:
    scripts/bench_gate.py [BENCH_serve.json]
    scripts/bench_gate.py --self-test

--self-test feeds the gate doctored rows AND doctored lab tables (a
collapsed planned/naive ratio, a flat thread speedup, an eventless
autoscale row, a within-noise shortfall that must be tolerated) and
asserts each one lands as it should, then feeds healthy sets and
asserts they pass — proof in CI that the gate *can* fail before it is
trusted to pass.
"""

import json
import math
import os
import sys

PLANNED_RATIO_MIN = float(os.environ.get("GATE_PLANNED_RATIO_MIN", "2.0"))
THREAD_RATIO_MIN = float(os.environ.get("GATE_THREAD_RATIO_MIN", "1.5"))
SIMD_RATIO_MIN = float(os.environ.get("GATE_SIMD_RATIO_MIN", "1.3"))
ENGINES = ("float", "shift6")


def _is_baseline(r, executor, engine, threads, simd):
    """Shared closed-loop cell filter for flat rows and table cells."""
    return (
        r.get("executor") == executor
        and r.get("engine") == engine
        and r.get("shards") == 1
        and r.get("threads") == threads
        and r.get("window") == "fixed"
        and r.get("batch_window_ms") == 2
        and "load" not in r
        # trained-checkpoint cells are a separate dimension; the
        # closed-loop baselines compare synth rows only
        and r.get("checkpoint") in (None, "synth")
        # chaos cells measure the fault domain, not the engine —
        # only fault-free rows are baseline material
        and r.get("faults") in (None, "none")
        # multi-model registry cells route through tenant queues
        # and (for swap rows) a mid-run generation turnover — not
        # the single-model configuration the baselines compare
        and "models" not in r
        and (simd is None or r.get("simd", "off") == simd)
    )


def closed_loop_rate(rows, executor, engine, threads, simd=None):
    """img/s of the classic closed-loop cell (1 shard, fixed 2ms).

    `simd=None` matches any backend (first row wins — the sweep emits
    the detected-backend cells first, so the pre-SIMD checks keep
    comparing the production configuration); `"on"`/`"off"` pins the
    kernel backend, with rows from before the SIMD PR counting as
    `"off"`.
    """
    for r in rows:
        if _is_baseline(r, executor, engine, threads, simd):
            return r.get("imgs_per_s", 0.0)
    return None


def table_rate(cells, executor, engine, threads, simd=None):
    """(mean, std) img/s of the closed-loop cell from a lab table.

    `simd=None` prefers the detected-backend (`"on"`) cell when both
    backends are present, matching `closed_loop_rate`'s production-
    configuration bias.
    """
    fallback = None
    for c in cells:
        if not _is_baseline(c, executor, engine, threads, simd):
            continue
        m = c.get("metrics", {}).get("imgs_per_s", {})
        stat = (m.get("mean", 0.0), m.get("std", 0.0))
        if c.get("simd") == "on":
            return stat
        if fallback is None:
            fallback = stat
    return fallback


def ratio_shortfall(num, den, floor):
    """Variance-aware ratio floor on (mean, std) pairs.

    Fails only when `floor - num/den`, expressed in img/s as
    `floor * den.mean - num.mean`, is positive AND exceeds the pooled
    std `sqrt(num.std^2 + floor^2 * den.std^2)` — i.e. the shortfall
    is larger than the measured cell noise.

    Returns (fails, ratio, margin, pooled).
    """
    margin = floor * den[0] - num[0]
    pooled = math.sqrt(num[1] ** 2 + (floor**2) * den[1] ** 2)
    ratio = num[0] / den[0] if den[0] > 0 else float("nan")
    fails = den[0] <= 0 or (margin > 0 and margin > pooled)
    return fails, ratio, margin, pooled


def check_ratios(rows):
    """Strict (single-shot) ratio floors on flat rows."""
    failures = []
    for engine in ENGINES:
        planned = closed_loop_rate(rows, "planned", engine, 1)
        naive = closed_loop_rate(rows, "naive", engine, 1)
        if planned is None or naive is None:
            failures.append(
                f"{engine}: missing closed-loop planned/naive 1-shard rows "
                "(did the sweep run?)"
            )
        elif naive <= 0 or planned / naive < PLANNED_RATIO_MIN:
            ratio = planned / naive if naive > 0 else float("nan")
            failures.append(
                f"{engine}: planned/naive single-shard ratio {ratio:.2f}x "
                f"< {PLANNED_RATIO_MIN}x floor"
            )
        t1 = closed_loop_rate(rows, "planned", engine, 1)
        t4 = closed_loop_rate(rows, "planned", engine, 4)
        if t1 is None or t4 is None:
            failures.append(f"{engine}: missing planned 1-thread/4-thread rows")
        elif t1 <= 0 or t4 / t1 < THREAD_RATIO_MIN:
            ratio = t4 / t1 if t1 > 0 else float("nan")
            failures.append(
                f"{engine}: planned 4-thread/1-thread speedup {ratio:.2f}x "
                f"< {THREAD_RATIO_MIN}x floor"
            )
    # simd/scalar ratio on the shift engine — the ISSUE-7 deployment
    # claim. Gated only when the sweep actually ran a SIMD backend.
    simd_on = closed_loop_rate(rows, "planned", "shift6", 1, simd="on")
    if simd_on is not None:
        simd_off = closed_loop_rate(rows, "planned", "shift6", 1, simd="off")
        if simd_off is None:
            failures.append(
                "shift6: simd-on rows present but the forced-scalar baseline "
                "row (planned, 1 shard, 1 thread, simd off) is missing — "
                "the ratio has no denominator"
            )
        elif simd_off <= 0 or simd_on / simd_off < SIMD_RATIO_MIN:
            ratio = simd_on / simd_off if simd_off > 0 else float("nan")
            failures.append(
                f"shift6: planned simd/scalar single-shard ratio {ratio:.2f}x "
                f"< {SIMD_RATIO_MIN}x floor"
            )
    return failures


def check_table_ratios(cells):
    """Variance-aware ratio floors on lab-table cells (means, pooled
    std margins)."""
    failures = []
    for engine in ENGINES:
        planned = table_rate(cells, "planned", engine, 1)
        naive = table_rate(cells, "naive", engine, 1)
        if planned is None or naive is None:
            failures.append(
                f"{engine}: missing closed-loop planned/naive 1-shard cells "
                "(did the sweep run?)"
            )
        else:
            fails, ratio, margin, pooled = ratio_shortfall(
                planned, naive, PLANNED_RATIO_MIN
            )
            if fails:
                failures.append(
                    f"{engine}: planned/naive single-shard ratio {ratio:.2f}x "
                    f"< {PLANNED_RATIO_MIN}x floor by {margin:.1f} img/s "
                    f"(> pooled std {pooled:.1f})"
                )
        t4 = table_rate(cells, "planned", engine, 4)
        if planned is None or t4 is None:
            failures.append(f"{engine}: missing planned 1-thread/4-thread cells")
        else:
            fails, ratio, margin, pooled = ratio_shortfall(
                t4, planned, THREAD_RATIO_MIN
            )
            if fails:
                failures.append(
                    f"{engine}: planned 4-thread/1-thread speedup {ratio:.2f}x "
                    f"< {THREAD_RATIO_MIN}x floor by {margin:.1f} img/s "
                    f"(> pooled std {pooled:.1f})"
                )
    simd_on = table_rate(cells, "planned", "shift6", 1, simd="on")
    if simd_on is not None:
        simd_off = table_rate(cells, "planned", "shift6", 1, simd="off")
        if simd_off is None:
            failures.append(
                "shift6: simd-on cells present but the forced-scalar baseline "
                "cell (planned, 1 shard, 1 thread, simd off) is missing — "
                "the ratio has no denominator"
            )
        else:
            fails, ratio, margin, pooled = ratio_shortfall(
                simd_on, simd_off, SIMD_RATIO_MIN
            )
            if fails:
                failures.append(
                    f"shift6: planned simd/scalar single-shard ratio "
                    f"{ratio:.2f}x < {SIMD_RATIO_MIN}x floor by {margin:.1f} "
                    f"img/s (> pooled std {pooled:.1f})"
                )
    return failures


def check_markers(rows):
    """Absolute per-trial invariants (fault, registry, autoscale rows).

    These hold on EVERY repeat — they are checked on the flat rows even
    when a lab table is present.
    """
    failures = []
    for r in rows:
        if "faults" in r:
            crashes = r.get("crashes", 0)
            respawns = r.get("respawns", 0)
            lost = r.get("lost", 0)
            label = f"fault row ({r.get('engine')}, faults {r.get('faults')})"
            if crashes > 0 and lost > 0:
                failures.append(
                    f"{label}: {crashes} crash(es) with {lost} lost "
                    "response(s) — a caught panic must never cost a client "
                    "its response"
                )
            if crashes > 0 and respawns < 1:
                failures.append(
                    f"{label}: {crashes} crash(es) but 0 respawns — the "
                    "pool must replace crashed generations"
                )
            if r.get("faults") == "storm" and crashes < 1:
                failures.append(
                    f"{label}: storm row recorded no crashes — the "
                    "fault-injection harness never fired"
                )
    for r in rows:
        if "models" not in r:
            continue
        label = f"registry row (models {r.get('models')})"
        if "swaps" in r:
            swaps = r.get("swaps", 0)
            lost = r.get("lost", 0)
            if lost > 0:
                failures.append(
                    f"{label}: {lost} lost response(s) across {swaps} hot "
                    "swap(s) — a checkpoint swap must never cost a client "
                    "its response"
                )
            if swaps < 1:
                failures.append(
                    f"{label}: swap row recorded no swaps — the "
                    "hot-swap harness never fired"
                )
        if "tenant_mix" in r:
            counts = r.get("tenant_counts", [])
            if not counts:
                failures.append(
                    f"{label}: tenant row (mix {r.get('tenant_mix')}) "
                    "carries no dequeue counts"
                )
            for t, n in enumerate(counts):
                if n < 1:
                    failures.append(
                        f"{label}: tenant {t} (mix {r.get('tenant_mix')}) "
                        "recorded zero dequeues — the weighted-fair "
                        "arbiter starved a listed class"
                    )
    for r in rows:
        if r.get("shards") == "auto":
            ups = r.get("scale_ups", 0)
            downs = r.get("scale_downs", 0)
            if ups < 1 or downs < 1:
                failures.append(
                    f"autoscale row ({r.get('engine')}, load {r.get('load')}): "
                    f"{ups} scale-up(s) / {downs} drain(s) — the supervisor "
                    "must both spawn under bursts and drain in the gaps"
                )
    return failures


def check(rows):
    """Legacy single-shot gate: strict ratios + invariants on rows."""
    return check_ratios(rows) + check_markers(rows)


def check_doc(doc):
    """Gate a whole BENCH_serve.json document.

    Lab exports (with `"tables"`) get variance-aware ratio floors on
    the per-cell means; flat pre-lab files get the strict single-shot
    floors. Invariant rules always run on the flat rows.
    """
    rows = doc.get("rows", [])
    tables = doc.get("tables")
    if tables is not None:
        failures = check_table_ratios(tables.get("cells", []))
    else:
        failures = check_ratios(rows)
    return failures + check_markers(rows)


def healthy_rows():
    base = {"window": "fixed", "batch_window_ms": 2}
    rows = []
    for engine in ENGINES:
        rows += [
            dict(base, executor="planned", engine=engine, shards=1, threads=1, imgs_per_s=300.0,
                 simd="on"),
            dict(base, executor="naive", engine=engine, shards=1, threads=1, imgs_per_s=100.0,
                 simd="off"),
            dict(base, executor="planned", engine=engine, shards=1, threads=4, imgs_per_s=600.0,
                 simd="on"),
        ]
    # the forced-scalar baseline the simd gate divides by (300/200 = 1.5x)
    rows.append(
        dict(base, executor="planned", engine="shift6", shards=1, threads=1, imgs_per_s=200.0,
             simd="off")
    )
    rows.append(
        dict(
            base,
            executor="planned",
            engine="shift6",
            shards="auto",
            threads=1,
            load="bursty",
            scale_ups=2,
            scale_downs=1,
        )
    )
    # the fault sweep's twin rows: fault-free control + panic storm
    # (crashes happened, every one respawned, nothing lost)
    rows.append(
        dict(base, executor="planned", engine="shift6", shards=1, threads=1,
             imgs_per_s=290.0, simd="on", faults="none", crashes=0,
             respawns=0, lost=0)
    )
    rows.append(
        dict(base, executor="planned", engine="shift6", shards=1, threads=1,
             imgs_per_s=240.0, simd="on", faults="storm", crashes=3,
             respawns=3, lost=0)
    )
    # the multi-model registry rows: a mixed-tenant cell (every listed
    # tenant saw dequeues) and a hot-swap cell (swaps landed, nothing
    # lost)
    rows.append(
        dict(base, executor="planned", engine="multi", shards=2, threads=1,
             imgs_per_s=250.0, simd="on", models="hi=shift6+lo=shift2",
             resident_weight_bytes=1000, tenant_mix="3:1",
             tenant_counts=[36, 12], tenant_p95_ms=[8.0, 14.0])
    )
    rows.append(
        dict(base, executor="planned", engine="shift6", shards=2, threads=1,
             imgs_per_s=260.0, simd="on", models="m6=shift6",
             resident_weight_bytes=750, swaps=2, lost=0)
    )
    return rows


def _cell(executor, engine, threads, simd, mean, std):
    return {
        "executor": executor,
        "engine": engine,
        "shards": 1,
        "threads": threads,
        "window": "fixed",
        "batch_window_ms": 2,
        "simd": simd,
        "n": 2,
        "metrics": {
            "imgs_per_s": {
                "mean": mean, "std": std, "min": mean - std, "max": mean + std,
            }
        },
    }


def healthy_cells():
    """A lab-table shape of the healthy closed-loop baselines, with
    the noise the repeats actually measured."""
    cells = []
    for engine in ENGINES:
        cells.append(_cell("planned", engine, 1, "on", 300.0, 8.0))
        cells.append(_cell("naive", engine, 1, "off", 100.0, 4.0))
        cells.append(_cell("planned", engine, 4, "on", 600.0, 12.0))
    # the forced-scalar simd denominator (300/200 = 1.5x)
    cells.append(_cell("planned", "shift6", 1, "off", 200.0, 6.0))
    return cells


def healthy_doc():
    return {
        "rows": healthy_rows(),
        "tables": {"table": "serve", "cells": healthy_cells()},
    }


def self_test():
    assert check(healthy_rows()) == [], "healthy trajectory must pass the gate"

    # injected regression 1: planned/naive ratio collapses to ~1.1x
    doctored = healthy_rows()
    for r in doctored:
        if r["executor"] == "naive" and r["engine"] == "shift6":
            r["imgs_per_s"] = 280.0
    fails = check(doctored)
    assert any("planned/naive" in f and "shift6" in f for f in fails), fails

    # injected regression 2: thread speedup collapses to 1.0x
    doctored = healthy_rows()
    for r in doctored:
        if r["executor"] == "planned" and r["threads"] == 4 and r["engine"] == "float":
            r["imgs_per_s"] = 300.0
    fails = check(doctored)
    assert any("4-thread/1-thread" in f and "float" in f for f in fails), fails

    # injected regression 3: the elastic supervisor never drains
    doctored = healthy_rows()
    for r in doctored:
        if r.get("shards") == "auto":
            r["scale_downs"] = 0
    fails = check(doctored)
    assert any("autoscale" in f for f in fails), fails

    # injected regression 4: the sweep silently lost its naive rows
    doctored = [r for r in healthy_rows() if r["executor"] != "naive"]
    fails = check(doctored)
    assert any("missing" in f for f in fails), fails

    # injected regression 5: the simd/scalar ratio collapses to ~1.07x
    doctored = healthy_rows()
    for r in doctored:
        if r.get("simd") == "off" and r["executor"] == "planned" and r["engine"] == "shift6":
            r["imgs_per_s"] = 280.0
    fails = check(doctored)
    assert any("simd/scalar" in f for f in fails), fails

    # injected regression 6: simd-on rows without the scalar baseline
    doctored = [
        r
        for r in healthy_rows()
        if not (r.get("simd") == "off" and r["executor"] == "planned")
    ]
    fails = check(doctored)
    assert any("no denominator" in f for f in fails), fails

    # injected regression 7: the crash storm lost responses
    doctored = healthy_rows()
    for r in doctored:
        if r.get("faults") == "storm":
            r["lost"] = 2
    fails = check(doctored)
    assert any("lost" in f for f in fails), fails

    # injected regression 8: crashes happened but nothing respawned
    doctored = healthy_rows()
    for r in doctored:
        if r.get("faults") == "storm":
            r["respawns"] = 0
    fails = check(doctored)
    assert any("0 respawns" in f for f in fails), fails

    # injected regression 9: the storm row shows the harness never fired
    doctored = healthy_rows()
    for r in doctored:
        if r.get("faults") == "storm":
            r["crashes"] = 0
            r["respawns"] = 0
    fails = check(doctored)
    assert any("never fired" in f for f in fails), fails

    # injected regression 10: the hot swap lost a response
    doctored = healthy_rows()
    for r in doctored:
        if "swaps" in r:
            r["lost"] = 1
    fails = check(doctored)
    assert any("hot" in f and "swap" in f for f in fails), fails

    # injected regression 11: the weighted-fair arbiter starved a tenant
    doctored = healthy_rows()
    for r in doctored:
        if "tenant_counts" in r:
            r["tenant_counts"] = [48, 0]
    fails = check(doctored)
    assert any("starved" in f for f in fails), fails

    # injected regression 12: the swap harness never fired
    doctored = healthy_rows()
    for r in doctored:
        if "swaps" in r:
            r["swaps"] = 0
    fails = check(doctored)
    assert any("hot-swap harness" in f for f in fails), fails

    # a pre-registry bench file (no "models" rows at all) must still
    # pass: the registry gate only judges rows carrying the marker
    premodel = [r for r in healthy_rows() if "models" not in r]
    assert check(premodel) == [], "pre-registry trajectory must pass (gate skipped)"

    # a pre-fault bench file (no "faults" rows at all) must still pass:
    # the fault gate only judges rows that carry the marker
    prefault = [r for r in healthy_rows() if "faults" not in r]
    assert check(prefault) == [], "pre-fault trajectory must pass (gate skipped)"

    # a pre-SIMD bench file (no "simd" fields at all) must still pass:
    # the simd gate skips, the legacy gates keep working
    stripped = []
    for r in healthy_rows():
        r = dict(r)
        r.pop("simd", None)
        stripped.append(r)
    assert check(stripped) == [], "simd-less trajectory must pass (gate skipped)"

    # ---- lab-table (variance-aware) mode ----

    # a healthy lab export passes, and a flat pre-lab document (no
    # "tables" key) still routes through the strict single-shot gate
    assert check_doc(healthy_doc()) == [], "healthy lab tables must pass the gate"
    assert check_doc({"rows": healthy_rows()}) == [], "flat pre-lab doc must pass"

    # table regression 1: the planned/naive mean collapses well past
    # the noise (2x floor missed by 260 img/s against ~17 pooled std)
    doc = healthy_doc()
    for c in doc["tables"]["cells"]:
        if c["executor"] == "naive" and c["engine"] == "shift6":
            c["metrics"]["imgs_per_s"]["mean"] = 280.0
    fails = check_doc(doc)
    assert any("planned/naive" in f and "shift6" in f for f in fails), fails

    # table tolerance: a ratio nominally below the floor (195/100 =
    # 1.95x < 2x) but within the pooled cell noise (margin 5 img/s vs
    # pooled std ~12.8) must NOT fail — that is the whole point of
    # variance-aware gating
    doc = healthy_doc()
    for c in doc["tables"]["cells"]:
        if c["executor"] == "planned" and c["engine"] == "float" and c["threads"] == 1:
            c["metrics"]["imgs_per_s"]["mean"] = 195.0
            c["metrics"]["imgs_per_s"]["std"] = 10.0
    assert check_doc(doc) == [], "within-noise shortfall must be tolerated"

    # table regression 2: the thread speedup collapses far past noise
    # (1.5x floor needs 450; 320 misses by 130 against ~14 pooled std)
    doc = healthy_doc()
    for c in doc["tables"]["cells"]:
        if c["executor"] == "planned" and c["engine"] == "float" and c["threads"] == 4:
            c["metrics"]["imgs_per_s"]["mean"] = 320.0
    fails = check_doc(doc)
    assert any("4-thread/1-thread" in f and "float" in f for f in fails), fails

    # table regression 3: the simd/scalar mean ratio collapses
    doc = healthy_doc()
    for c in doc["tables"]["cells"]:
        if c["simd"] == "off" and c["executor"] == "planned" and c["engine"] == "shift6":
            c["metrics"]["imgs_per_s"]["mean"] = 280.0
    fails = check_doc(doc)
    assert any("simd/scalar" in f for f in fails), fails

    # table regression 4: cells went missing entirely
    doc = healthy_doc()
    doc["tables"]["cells"] = [
        c for c in doc["tables"]["cells"] if c["executor"] != "naive"
    ]
    fails = check_doc(doc)
    assert any("missing" in f for f in fails), fails

    # invariants still run on the flat rows even in table mode
    doc = healthy_doc()
    for r in doc["rows"]:
        if r.get("faults") == "storm":
            r["lost"] = 2
    fails = check_doc(doc)
    assert any("lost" in f for f in fails), fails

    print(
        "bench_gate self-test: all injected regressions caught (rows and "
        "lab tables), within-noise shortfall tolerated, healthy sets pass"
    )


def main(argv):
    if len(argv) > 1 and argv[1] == "--self-test":
        self_test()
        return 0
    path = argv[1] if len(argv) > 1 else "BENCH_serve.json"
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get("rows", [])
    failures = check_doc(doc)
    if failures:
        print(f"bench gate FAILED on {path}:")
        for f in failures:
            print(f"  - {f}")
        return 1
    mode = (
        "variance-aware (lab tables, pooled-std margins)"
        if doc.get("tables") is not None
        else "single-shot"
    )
    simd_note = (
        f"simd/scalar >= {SIMD_RATIO_MIN}x"
        if closed_loop_rate(rows, "planned", "shift6", 1, simd="on") is not None
        else "simd gate skipped (no simd-on rows)"
    )
    fault_note = (
        "fault rows lose nothing"
        if any("faults" in r for r in rows)
        else "fault gate skipped (no fault rows)"
    )
    print(
        f"bench gate passed on {path} [{mode}]: planned/naive >= "
        f"{PLANNED_RATIO_MIN}x, 4t/1t >= {THREAD_RATIO_MIN}x, {simd_note}, "
        f"autoscale rows show scale events, {fault_note}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
