#!/usr/bin/env python3
"""Accuracy-trajectory regression gate for BENCH_train.json.

Parses the file `make bench-train-smoke` just wrote and FAILS (exit 1)
when the trained-checkpoint trajectory regresses below the floors the
ROADMAP commits to. All checks run on the **mean mAP over seeds** per
method (individual seeds are noisy at smoke scale):

  * coverage — every method in {float, ternary-exact, lbw-4, lbw-6,
    inq-6, dorefa-6} must appear on >= MIN_SEEDS distinct seeds, every
    mAP finite in [0, 1];
  * 6-bit fidelity — mean lbw-6 mAP >= mean float mAP - DELTA6 (the
    paper's headline: ~6 bits is nearly lossless);
  * ternary floor — mean ternary-exact mAP >= TERNARY_FLOOR (2-bit
    quantization degrades but must not destroy the detector);
  * monotone-in-bits sanity — mean mAP at 2 bits <= 4 bits + MONO_TOL
    and 4 bits <= 6 bits + MONO_TOL over the LBW family
    (ternary-exact, lbw-4, lbw-6).

Floors are overridable via env (GATE_DELTA6, GATE_TERNARY_FLOOR,
GATE_MONO_TOL, GATE_MIN_SEEDS) so a deliberate trade-off can be landed
without editing this script.

Usage:
    scripts/accuracy_gate.py [BENCH_train.json]
    scripts/accuracy_gate.py --self-test

--self-test feeds the gate doctored rows (a collapsed 6-bit mAP, a
missing method, a dead ternary detector, an inverted bit ordering, a
NaN mAP) and asserts each one is caught, then feeds a healthy set and
asserts it passes — proof in CI that the gate *can* fail before it is
trusted to pass.
"""

import json
import math
import os
import sys

DELTA6 = float(os.environ.get("GATE_DELTA6", "0.06"))
TERNARY_FLOOR = float(os.environ.get("GATE_TERNARY_FLOOR", "0.015"))
MONO_TOL = float(os.environ.get("GATE_MONO_TOL", "0.06"))
MIN_SEEDS = int(os.environ.get("GATE_MIN_SEEDS", "2"))

METHODS = ("float", "ternary-exact", "lbw-4", "lbw-6", "inq-6", "dorefa-6")


def mean_map(rows, method):
    """Mean mAP over seeds for one method, or None if absent."""
    maps = [r["map"] for r in rows if r.get("method") == method]
    return sum(maps) / len(maps) if maps else None


def check(rows):
    """Return a list of failure strings (empty = gate passes)."""
    failures = []
    for m in METHODS:
        seeds = {r.get("seed") for r in rows if r.get("method") == m}
        if len(seeds) < MIN_SEEDS:
            failures.append(
                f"{m}: only {len(seeds)} seed(s), need >= {MIN_SEEDS} "
                "(did the trajectory sweep run every method?)"
            )
    for r in rows:
        v = r.get("map")
        if v is None or not math.isfinite(v) or not 0.0 <= v <= 1.0:
            failures.append(
                f"{r.get('method')} seed {r.get('seed')}: mAP {v!r} is not "
                "a finite value in [0, 1]"
            )
    if failures:
        return failures  # means below would be meaningless

    float_map = mean_map(rows, "float")
    lbw6 = mean_map(rows, "lbw-6")
    ternary = mean_map(rows, "ternary-exact")
    lbw4 = mean_map(rows, "lbw-4")
    if lbw6 < float_map - DELTA6:
        failures.append(
            f"6-bit fidelity: mean lbw-6 mAP {lbw6:.4f} < "
            f"float {float_map:.4f} - {DELTA6} (quantization is no longer "
            "nearly lossless)"
        )
    if ternary < TERNARY_FLOOR:
        failures.append(
            f"ternary floor: mean ternary-exact mAP {ternary:.4f} < "
            f"{TERNARY_FLOOR} (2-bit training collapsed)"
        )
    if ternary > lbw4 + MONO_TOL:
        failures.append(
            f"bit monotonicity: 2-bit mean mAP {ternary:.4f} beats 4-bit "
            f"{lbw4:.4f} by more than {MONO_TOL}"
        )
    if lbw4 > lbw6 + MONO_TOL:
        failures.append(
            f"bit monotonicity: 4-bit mean mAP {lbw4:.4f} beats 6-bit "
            f"{lbw6:.4f} by more than {MONO_TOL}"
        )
    return failures


def healthy_rows():
    rows = []
    maps = {
        "float": 0.117,
        "ternary-exact": 0.091,
        "lbw-4": 0.130,
        "lbw-6": 0.161,
        "inq-6": 0.147,
        "dorefa-6": 0.157,
    }
    bits = {
        "float": 32, "ternary-exact": 2, "lbw-4": 4,
        "lbw-6": 6, "inq-6": 6, "dorefa-6": 6,
    }
    for seed in (17, 18):
        for m, v in maps.items():
            rows.append(
                {
                    "method": m,
                    "bits": bits[m],
                    "seed": seed,
                    "map": v + (0.01 if seed == 18 else -0.01),
                }
            )
    return rows


def self_test():
    assert check(healthy_rows()) == [], "healthy trajectory must pass the gate"

    # injected regression 1: 6-bit mAP collapses far below float
    doctored = healthy_rows()
    for r in doctored:
        if r["method"] == "lbw-6":
            r["map"] = 0.01
    fails = check(doctored)
    assert any("6-bit fidelity" in f for f in fails), fails

    # injected regression 2: a method silently dropped from the sweep
    doctored = [r for r in healthy_rows() if r["method"] != "inq-6"]
    fails = check(doctored)
    assert any("inq-6" in f and "seed" in f for f in fails), fails

    # injected regression 3: the ternary detector died
    doctored = healthy_rows()
    for r in doctored:
        if r["method"] == "ternary-exact":
            r["map"] = 0.001
    fails = check(doctored)
    assert any("ternary floor" in f for f in fails), fails

    # injected regression 4: bit ordering inverts (2-bit >> 6-bit)
    doctored = healthy_rows()
    for r in doctored:
        if r["method"] == "ternary-exact":
            r["map"] = 0.30
        if r["method"] == "lbw-6":
            r["map"] = 0.12
    fails = check(doctored)
    assert any("bit monotonicity" in f for f in fails), fails

    # injected regression 5: a NaN mAP sneaks into a row
    doctored = healthy_rows()
    doctored[0]["map"] = float("nan")
    fails = check(doctored)
    assert any("finite" in f for f in fails), fails

    # one seed only must also fail coverage
    doctored = [r for r in healthy_rows() if r["seed"] == 17]
    fails = check(doctored)
    assert any("seed(s)" in f for f in fails), fails

    print(
        "accuracy_gate self-test: all injected regressions caught, "
        "healthy set passes"
    )


def main(argv):
    if len(argv) > 1 and argv[1] == "--self-test":
        self_test()
        return 0
    path = argv[1] if len(argv) > 1 else "BENCH_train.json"
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get("rows", [])
    failures = check(rows)
    if failures:
        print(f"accuracy gate FAILED on {path}:")
        for f in failures:
            print(f"  - {f}")
        return 1
    summary = ", ".join(
        f"{m} {mean_map(rows, m):.4f}" for m in METHODS
    )
    print(
        f"accuracy gate passed on {path} (mean mAP over seeds): {summary}; "
        f"lbw-6 within {DELTA6} of float, ternary >= {TERNARY_FLOOR}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
