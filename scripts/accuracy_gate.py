#!/usr/bin/env python3
"""Accuracy-trajectory regression gate for BENCH_train.json.

Parses the file `make bench-train-smoke` (now a lab-driven run:
`repro lab run ci-smoke --only train`) just wrote and FAILS (exit 1)
when the trained-checkpoint trajectory regresses below the floors the
ROADMAP commits to. All checks run on the **mean mAP over seeds** per
method (individual seeds are noisy at smoke scale):

  * coverage — every method in {float, ternary-exact, lbw-4, lbw-6,
    inq-6, dorefa-6} must appear on >= MIN_SEEDS distinct seeds, every
    mAP finite in [0, 1];
  * 6-bit fidelity — mean lbw-6 mAP >= mean float mAP - DELTA6 (the
    paper's headline: ~6 bits is nearly lossless);
  * ternary floor — mean ternary-exact mAP >= TERNARY_FLOOR (2-bit
    quantization degrades but must not destroy the detector);
  * monotone-in-bits sanity — mean mAP at 2 bits <= 4 bits + MONO_TOL
    and 4 bits <= 6 bits + MONO_TOL over the LBW family
    (ternary-exact, lbw-4, lbw-6).

Variance-aware mode: a lab-exported document carries a `"tables"` key
with one cell per method holding the mAP mean/std over seeds. When
present, the floors above compare CELL MEANS and only fail when the
shortfall exceeds the pooled standard deviation of the cells involved
— a mean nominally below a floor but within seed-to-seed noise does
not fail CI, and a mean clearly below it still does. A flat pre-lab
document (no `"tables"`) falls back to the strict mean-of-rows
comparisons, unchanged.

Floors are overridable via env (GATE_DELTA6, GATE_TERNARY_FLOOR,
GATE_MONO_TOL, GATE_MIN_SEEDS) so a deliberate trade-off can be landed
without editing this script.

Usage:
    scripts/accuracy_gate.py [BENCH_train.json]
    scripts/accuracy_gate.py --self-test

--self-test feeds the gate doctored rows AND doctored lab tables (a
collapsed 6-bit mAP, a missing method, a dead ternary detector, an
inverted bit ordering, a NaN mAP, a within-noise 6-bit shortfall that
must be tolerated) and asserts each one lands as it should, then feeds
healthy sets and asserts they pass — proof in CI that the gate *can*
fail before it is trusted to pass.
"""

import json
import math
import os
import sys

DELTA6 = float(os.environ.get("GATE_DELTA6", "0.06"))
TERNARY_FLOOR = float(os.environ.get("GATE_TERNARY_FLOOR", "0.015"))
MONO_TOL = float(os.environ.get("GATE_MONO_TOL", "0.06"))
MIN_SEEDS = int(os.environ.get("GATE_MIN_SEEDS", "2"))

METHODS = ("float", "ternary-exact", "lbw-4", "lbw-6", "inq-6", "dorefa-6")


def mean_map(rows, method):
    """Mean mAP over seeds for one method, or None if absent."""
    maps = [r["map"] for r in rows if r.get("method") == method]
    return sum(maps) / len(maps) if maps else None


def check(rows):
    """Return a list of failure strings (empty = gate passes)."""
    failures = []
    for m in METHODS:
        seeds = {r.get("seed") for r in rows if r.get("method") == m}
        if len(seeds) < MIN_SEEDS:
            failures.append(
                f"{m}: only {len(seeds)} seed(s), need >= {MIN_SEEDS} "
                "(did the trajectory sweep run every method?)"
            )
    for r in rows:
        v = r.get("map")
        if v is None or not math.isfinite(v) or not 0.0 <= v <= 1.0:
            failures.append(
                f"{r.get('method')} seed {r.get('seed')}: mAP {v!r} is not "
                "a finite value in [0, 1]"
            )
    if failures:
        return failures  # means below would be meaningless

    float_map = mean_map(rows, "float")
    lbw6 = mean_map(rows, "lbw-6")
    ternary = mean_map(rows, "ternary-exact")
    lbw4 = mean_map(rows, "lbw-4")
    if lbw6 < float_map - DELTA6:
        failures.append(
            f"6-bit fidelity: mean lbw-6 mAP {lbw6:.4f} < "
            f"float {float_map:.4f} - {DELTA6} (quantization is no longer "
            "nearly lossless)"
        )
    if ternary < TERNARY_FLOOR:
        failures.append(
            f"ternary floor: mean ternary-exact mAP {ternary:.4f} < "
            f"{TERNARY_FLOOR} (2-bit training collapsed)"
        )
    if ternary > lbw4 + MONO_TOL:
        failures.append(
            f"bit monotonicity: 2-bit mean mAP {ternary:.4f} beats 4-bit "
            f"{lbw4:.4f} by more than {MONO_TOL}"
        )
    if lbw4 > lbw6 + MONO_TOL:
        failures.append(
            f"bit monotonicity: 4-bit mean mAP {lbw4:.4f} beats 6-bit "
            f"{lbw6:.4f} by more than {MONO_TOL}"
        )
    return failures


def method_stat(cells, method):
    """(mean, std, seed-count) of a method's mAP from lab-table cells,
    or None if the method has no cell."""
    for c in cells:
        if c.get("method") == method:
            m = c.get("metrics", {}).get("map", {})
            seeds = c.get("seeds", [])
            return (m.get("mean"), m.get("std", 0.0), len(seeds))
    return None


def check_cells(cells):
    """Variance-aware gate on lab-table cells (means, pooled-std
    margins over the seed axis)."""
    failures = []
    stats = {}
    for m in METHODS:
        s = method_stat(cells, m)
        if s is None or s[2] < MIN_SEEDS:
            n = 0 if s is None else s[2]
            failures.append(
                f"{m}: only {n} seed(s), need >= {MIN_SEEDS} "
                "(did the trajectory sweep run every method?)"
            )
            continue
        if s[0] is None or not math.isfinite(s[0]) or not 0.0 <= s[0] <= 1.0:
            failures.append(
                f"{m}: mean mAP {s[0]!r} is not a finite value in [0, 1]"
            )
            continue
        stats[m] = s
    if failures:
        return failures  # margins below would be meaningless

    def margin_fails(shortfall, *stds):
        pooled = math.sqrt(sum(s**2 for s in stds))
        return shortfall > 0 and shortfall > pooled, pooled

    float_map, lbw6 = stats["float"], stats["lbw-6"]
    ternary, lbw4 = stats["ternary-exact"], stats["lbw-4"]
    fails, pooled = margin_fails(
        (float_map[0] - DELTA6) - lbw6[0], float_map[1], lbw6[1]
    )
    if fails:
        failures.append(
            f"6-bit fidelity: mean lbw-6 mAP {lbw6[0]:.4f} < "
            f"float {float_map[0]:.4f} - {DELTA6} by more than the pooled "
            f"seed std {pooled:.4f} (quantization is no longer nearly "
            "lossless)"
        )
    fails, pooled = margin_fails(TERNARY_FLOOR - ternary[0], ternary[1])
    if fails:
        failures.append(
            f"ternary floor: mean ternary-exact mAP {ternary[0]:.4f} < "
            f"{TERNARY_FLOOR} by more than the seed std {pooled:.4f} "
            "(2-bit training collapsed)"
        )
    fails, pooled = margin_fails(
        ternary[0] - (lbw4[0] + MONO_TOL), ternary[1], lbw4[1]
    )
    if fails:
        failures.append(
            f"bit monotonicity: 2-bit mean mAP {ternary[0]:.4f} beats 4-bit "
            f"{lbw4[0]:.4f} by more than {MONO_TOL} + pooled std {pooled:.4f}"
        )
    fails, pooled = margin_fails(
        lbw4[0] - (lbw6[0] + MONO_TOL), lbw4[1], lbw6[1]
    )
    if fails:
        failures.append(
            f"bit monotonicity: 4-bit mean mAP {lbw4[0]:.4f} beats 6-bit "
            f"{lbw6[0]:.4f} by more than {MONO_TOL} + pooled std {pooled:.4f}"
        )
    return failures


def check_doc(doc):
    """Gate a whole BENCH_train.json document: lab exports (with
    `"tables"`) get the variance-aware cell gate, flat pre-lab files
    the strict mean-of-rows gate."""
    tables = doc.get("tables")
    if tables is not None:
        return check_cells(tables.get("cells", []))
    return check(doc.get("rows", []))


HEALTHY_MAPS = {
    "float": 0.117,
    "ternary-exact": 0.091,
    "lbw-4": 0.130,
    "lbw-6": 0.161,
    "inq-6": 0.147,
    "dorefa-6": 0.157,
}
HEALTHY_BITS = {
    "float": 32, "ternary-exact": 2, "lbw-4": 4,
    "lbw-6": 6, "inq-6": 6, "dorefa-6": 6,
}


def healthy_rows():
    rows = []
    for seed in (17, 18):
        for m, v in HEALTHY_MAPS.items():
            rows.append(
                {
                    "method": m,
                    "bits": HEALTHY_BITS[m],
                    "seed": seed,
                    "map": v + (0.01 if seed == 18 else -0.01),
                }
            )
    return rows


def healthy_cells():
    """The lab-table shape of the healthy trajectory: one cell per
    method, mAP aggregated over seeds (sample std of ±0.01 = ~0.0141)."""
    cells = []
    for m, v in HEALTHY_MAPS.items():
        cells.append(
            {
                "method": m,
                "bits": HEALTHY_BITS[m],
                "n": 2,
                "seeds": [17, 18],
                "metrics": {
                    "map": {"mean": v, "std": 0.01414, "min": v - 0.01, "max": v + 0.01}
                },
            }
        )
    return cells


def healthy_doc():
    return {
        "rows": healthy_rows(),
        "tables": {"table": "train", "cells": healthy_cells()},
    }


def self_test():
    assert check(healthy_rows()) == [], "healthy trajectory must pass the gate"

    # injected regression 1: 6-bit mAP collapses far below float
    doctored = healthy_rows()
    for r in doctored:
        if r["method"] == "lbw-6":
            r["map"] = 0.01
    fails = check(doctored)
    assert any("6-bit fidelity" in f for f in fails), fails

    # injected regression 2: a method silently dropped from the sweep
    doctored = [r for r in healthy_rows() if r["method"] != "inq-6"]
    fails = check(doctored)
    assert any("inq-6" in f and "seed" in f for f in fails), fails

    # injected regression 3: the ternary detector died
    doctored = healthy_rows()
    for r in doctored:
        if r["method"] == "ternary-exact":
            r["map"] = 0.001
    fails = check(doctored)
    assert any("ternary floor" in f for f in fails), fails

    # injected regression 4: bit ordering inverts (2-bit >> 6-bit)
    doctored = healthy_rows()
    for r in doctored:
        if r["method"] == "ternary-exact":
            r["map"] = 0.30
        if r["method"] == "lbw-6":
            r["map"] = 0.12
    fails = check(doctored)
    assert any("bit monotonicity" in f for f in fails), fails

    # injected regression 5: a NaN mAP sneaks into a row
    doctored = healthy_rows()
    doctored[0]["map"] = float("nan")
    fails = check(doctored)
    assert any("finite" in f for f in fails), fails

    # one seed only must also fail coverage
    doctored = [r for r in healthy_rows() if r["seed"] == 17]
    fails = check(doctored)
    assert any("seed(s)" in f for f in fails), fails

    # ---- lab-table (variance-aware) mode ----

    # a healthy lab export passes, and a flat pre-lab document (no
    # "tables" key) still routes through the strict mean-of-rows gate
    assert check_doc(healthy_doc()) == [], "healthy lab tables must pass the gate"
    assert check_doc({"rows": healthy_rows()}) == [], "flat pre-lab doc must pass"

    # table regression 1: the 6-bit mean collapses far past seed noise
    doc = healthy_doc()
    for c in doc["tables"]["cells"]:
        if c["method"] == "lbw-6":
            c["metrics"]["map"]["mean"] = 0.01
    fails = check_doc(doc)
    assert any("6-bit fidelity" in f for f in fails), fails

    # table tolerance: a 6-bit mean nominally below float - DELTA6
    # (by 0.005) but within the pooled seed std (~0.0173) must NOT
    # fail — that is the whole point of variance-aware gating
    doc = healthy_doc()
    for c in doc["tables"]["cells"]:
        if c["method"] == "lbw-6":
            c["metrics"]["map"]["mean"] = HEALTHY_MAPS["float"] - DELTA6 - 0.005
            c["metrics"]["map"]["std"] = 0.01
        if c["method"] == "lbw-4":
            # keep 4-bit just below the lowered 6-bit cell so only the
            # fidelity margin is in play
            c["metrics"]["map"]["mean"] = 0.05
    assert check_doc(doc) == [], "within-noise 6-bit shortfall must be tolerated"

    # table regression 2: a method cell went missing
    doc = healthy_doc()
    doc["tables"]["cells"] = [
        c for c in doc["tables"]["cells"] if c["method"] != "inq-6"
    ]
    fails = check_doc(doc)
    assert any("inq-6" in f and "seed" in f for f in fails), fails

    # table regression 3: a cell covers only one seed
    doc = healthy_doc()
    for c in doc["tables"]["cells"]:
        if c["method"] == "dorefa-6":
            c["seeds"] = [17]
    fails = check_doc(doc)
    assert any("dorefa-6" in f and "seed(s)" in f for f in fails), fails

    # table regression 4: the ternary detector died (mean far below
    # the floor, past its own seed std)
    doc = healthy_doc()
    for c in doc["tables"]["cells"]:
        if c["method"] == "ternary-exact":
            c["metrics"]["map"]["mean"] = 0.0001
            c["metrics"]["map"]["std"] = 0.0001
    fails = check_doc(doc)
    assert any("ternary floor" in f for f in fails), fails

    # table regression 5: bit ordering inverts past noise
    doc = healthy_doc()
    for c in doc["tables"]["cells"]:
        if c["method"] == "ternary-exact":
            c["metrics"]["map"]["mean"] = 0.30
        if c["method"] == "lbw-6":
            c["metrics"]["map"]["mean"] = 0.12
    fails = check_doc(doc)
    assert any("bit monotonicity" in f for f in fails), fails

    # table regression 6: a NaN mean in a cell
    doc = healthy_doc()
    doc["tables"]["cells"][0]["metrics"]["map"]["mean"] = float("nan")
    fails = check_doc(doc)
    assert any("finite" in f for f in fails), fails

    print(
        "accuracy_gate self-test: all injected regressions caught (rows and "
        "lab tables), within-noise shortfall tolerated, healthy sets pass"
    )


def main(argv):
    if len(argv) > 1 and argv[1] == "--self-test":
        self_test()
        return 0
    path = argv[1] if len(argv) > 1 else "BENCH_train.json"
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get("rows", [])
    failures = check_doc(doc)
    if failures:
        print(f"accuracy gate FAILED on {path}:")
        for f in failures:
            print(f"  - {f}")
        return 1
    mode = (
        "variance-aware (lab tables, pooled-std margins)"
        if doc.get("tables") is not None
        else "strict means"
    )
    summary = ", ".join(
        f"{m} {mean_map(rows, m):.4f}" for m in METHODS if mean_map(rows, m) is not None
    )
    print(
        f"accuracy gate passed on {path} [{mode}] (mean mAP over seeds): "
        f"{summary}; lbw-6 within {DELTA6} of float, ternary >= {TERNARY_FLOOR}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
